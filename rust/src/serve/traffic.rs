//! Open-loop arrival generation for the serving engine: Poisson,
//! bursty (two-state Markov-modulated Poisson), non-stationary
//! diurnal/flash-crowd shapes (Lewis–Shedler thinning over a
//! time-varying rate) and trace replay, all driven by a seeded
//! [`XorShift`] so a `(spec, tenants)` pair always produces the same
//! request stream.
//!
//! Rate semantics: a rate of **zero is valid everywhere** and simply
//! emits no arrivals (a diurnal trough, a drained autoscaler segment);
//! negative or non-finite rates are rejected at spec validation.  The
//! spec constructors validate eagerly, and [`generate`] re-validates,
//! so literally-constructed specs cannot smuggle a division by zero
//! into [`exp_variate`].

use crate::testutil::XorShift;
use crate::workloads::ModelGraph;

/// One tenant served by the engine: a model plus a traffic/partition
/// weight (relative share of the request mix and of the pod budget).
#[derive(Clone, Debug)]
pub struct Tenant {
    /// Display name (defaults to the model name).
    pub name: String,
    /// The model every request of this tenant runs (batch dimension is
    /// applied by the engine's batcher, not stored here).
    pub model: ModelGraph,
    /// Relative weight for traffic mixing and pod partitioning.
    pub weight: f64,
}

impl Tenant {
    /// Tenant named after its model.
    pub fn new(model: ModelGraph, weight: f64) -> Self {
        debug_assert!(weight > 0.0, "tenant weight must be positive");
        Tenant { name: model.name.clone(), model, weight }
    }
}

/// One request arrival.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    /// Arrival time in seconds from the start of the trace.
    pub t: f64,
    /// Index into the engine's tenant list.
    pub tenant: usize,
    /// Unique request id.
    pub id: u64,
    /// Requested batch units (1 for online requests; offline wrappers
    /// may carry pre-batched requests).
    pub batch: usize,
}

/// The arrival process shape.
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant offered rate (requests/s
    /// across all tenants; tenants sampled by weight).
    Poisson { qps: f64 },
    /// Two-state Markov-modulated Poisson process: `base_qps` in the
    /// quiet state, `burst_qps` during bursts, with exponentially
    /// distributed state holding times.
    Bursty {
        base_qps: f64,
        burst_qps: f64,
        /// Mean burst duration in seconds.
        mean_burst_s: f64,
        /// Mean quiet-period duration in seconds.
        mean_quiet_s: f64,
    },
    /// Diurnal sinusoid: a Poisson process whose rate is modulated as
    /// `base_qps · (1 + amplitude · sin(2π·t / period_s))` — the
    /// day/night cycle scaled into simulation time.  `amplitude` in
    /// `[0, 1]`; at amplitude 1 the trough rate is exactly zero and
    /// emits no arrivals.
    Diurnal {
        /// Mean offered rate (requests/s) — the sinusoid's midline.
        base_qps: f64,
        /// Relative swing in `[0, 1]` (0 degenerates to Poisson).
        amplitude: f64,
        /// Full cycle length in seconds.
        period_s: f64,
    },
    /// Flash crowd: a constant `base_qps` Poisson floor plus an
    /// additive `spike_qps` rectangle over `[t_spike, t_spike +
    /// spike_s)` — a news event landing on a steady fleet.
    FlashCrowd {
        /// Steady background rate (requests/s); 0 = spike only.
        base_qps: f64,
        /// Additional rate during the spike window (requests/s).
        spike_qps: f64,
        /// Spike start time in seconds.
        t_spike: f64,
        /// Spike width in seconds.
        spike_s: f64,
    },
    /// Replay an explicit trace (clamped to the spec duration; ids are
    /// reassigned sequentially).
    Trace(Vec<Arrival>),
}

/// Assert `v` is a finite, non-negative rate (requests/s).  Zero is
/// legal — it means "no arrivals" — but negative and non-finite rates
/// would turn [`exp_variate`] into NaN/∞ timestamps.
fn assert_rate(v: f64, what: &str) {
    assert!(
        v.is_finite() && v >= 0.0,
        "{what} must be a finite rate >= 0 (got {v})"
    );
}

/// A complete traffic specification.
#[derive(Clone, Debug)]
pub struct TrafficSpec {
    pub process: ArrivalProcess,
    /// Trace horizon in seconds: no arrivals at or beyond this time.
    pub duration_s: f64,
    /// RNG seed; equal seeds produce byte-identical traces.
    pub seed: u64,
}

impl TrafficSpec {
    /// Poisson spec shorthand (validated; `qps` 0 emits no arrivals).
    pub fn poisson(qps: f64, duration_s: f64, seed: u64) -> Self {
        let spec = TrafficSpec { process: ArrivalProcess::Poisson { qps }, duration_s, seed };
        spec.validate();
        spec
    }

    /// Bursty spec shorthand (validated).
    pub fn bursty(
        base_qps: f64,
        burst_qps: f64,
        mean_burst_s: f64,
        mean_quiet_s: f64,
        duration_s: f64,
        seed: u64,
    ) -> Self {
        let spec = TrafficSpec {
            process: ArrivalProcess::Bursty { base_qps, burst_qps, mean_burst_s, mean_quiet_s },
            duration_s,
            seed,
        };
        spec.validate();
        spec
    }

    /// Diurnal sinusoid spec shorthand (validated).
    pub fn diurnal(
        base_qps: f64,
        amplitude: f64,
        period_s: f64,
        duration_s: f64,
        seed: u64,
    ) -> Self {
        let spec = TrafficSpec {
            process: ArrivalProcess::Diurnal { base_qps, amplitude, period_s },
            duration_s,
            seed,
        };
        spec.validate();
        spec
    }

    /// Flash-crowd spec shorthand (validated).
    pub fn flash_crowd(
        base_qps: f64,
        spike_qps: f64,
        t_spike: f64,
        spike_s: f64,
        duration_s: f64,
        seed: u64,
    ) -> Self {
        let spec = TrafficSpec {
            process: ArrivalProcess::FlashCrowd { base_qps, spike_qps, t_spike, spike_s },
            duration_s,
            seed,
        };
        spec.validate();
        spec
    }

    /// Panic (with a precise message) on any parameter that would
    /// corrupt generation: negative/non-finite rates, non-positive
    /// state/period durations, out-of-range diurnal amplitude.  Called
    /// by every constructor *and* by [`generate`], so specs built as
    /// struct literals are checked too.  Zero rates are valid (they
    /// emit no arrivals) — the bug this guards against is release-mode
    /// `exp_variate(rate = 0)` silently producing ∞ timestamps.
    pub fn validate(&self) {
        assert!(
            self.duration_s.is_finite() && self.duration_s >= 0.0,
            "duration_s must be finite and >= 0 (got {})",
            self.duration_s
        );
        match &self.process {
            ArrivalProcess::Poisson { qps } => assert_rate(*qps, "Poisson qps"),
            ArrivalProcess::Bursty { base_qps, burst_qps, mean_burst_s, mean_quiet_s } => {
                assert_rate(*base_qps, "Bursty base_qps");
                assert_rate(*burst_qps, "Bursty burst_qps");
                assert!(
                    mean_burst_s.is_finite() && *mean_burst_s > 0.0,
                    "mean_burst_s must be finite and > 0 (got {mean_burst_s})"
                );
                assert!(
                    mean_quiet_s.is_finite() && *mean_quiet_s > 0.0,
                    "mean_quiet_s must be finite and > 0 (got {mean_quiet_s})"
                );
            }
            ArrivalProcess::Diurnal { base_qps, amplitude, period_s } => {
                assert_rate(*base_qps, "Diurnal base_qps");
                assert!(
                    (0.0..=1.0).contains(amplitude),
                    "Diurnal amplitude must lie in [0, 1] (got {amplitude})"
                );
                assert!(
                    period_s.is_finite() && *period_s > 0.0,
                    "Diurnal period_s must be finite and > 0 (got {period_s})"
                );
            }
            ArrivalProcess::FlashCrowd { base_qps, spike_qps, t_spike, spike_s } => {
                assert_rate(*base_qps, "FlashCrowd base_qps");
                assert_rate(*spike_qps, "FlashCrowd spike_qps");
                assert!(
                    t_spike.is_finite() && *t_spike >= 0.0,
                    "FlashCrowd t_spike must be finite and >= 0 (got {t_spike})"
                );
                assert!(
                    spike_s.is_finite() && *spike_s >= 0.0,
                    "FlashCrowd spike_s must be finite and >= 0 (got {spike_s})"
                );
            }
            ArrivalProcess::Trace(_) => {}
        }
    }
}

/// Exponential variate with the given rate (events/s).  Callers must
/// guard rate 0 (skip the segment) — this holds in release too, not
/// just under `debug_assert`: a zero rate here would silently yield an
/// ∞ timestamp and corrupt the stream.
fn exp_variate(rng: &mut XorShift, rate: f64) -> f64 {
    assert!(rate > 0.0 && rate.is_finite(), "exp_variate rate {rate}");
    // 1 - U lies in (0, 1], so ln() is finite and the variate >= 0.
    -(1.0 - rng.f64()).ln() / rate
}

/// Sample a tenant index by weight.
fn sample_tenant(rng: &mut XorShift, cum_weights: &[f64]) -> usize {
    let total = *cum_weights.last().expect("at least one tenant");
    let r = rng.f64() * total;
    cum_weights.iter().position(|&c| r < c).unwrap_or(cum_weights.len() - 1)
}

/// Lewis–Shedler thinning: draw candidate arrivals from a homogeneous
/// Poisson process at `rate_max`, accept each at probability
/// `rate_at(t) / rate_max`.  Exact for any bounded time-varying rate;
/// a zero `rate_max` (rate identically zero) emits nothing.
fn thinned(
    rng: &mut XorShift,
    rate_max: f64,
    rate_at: impl Fn(f64) -> f64,
    duration_s: f64,
    cum: &[f64],
    out: &mut Vec<Arrival>,
) {
    if rate_max <= 0.0 {
        return;
    }
    let mut t = exp_variate(rng, rate_max);
    while t < duration_s {
        // Fixed draw order (accept, then tenant) keeps streams
        // seed-deterministic regardless of the acceptance outcome's
        // data dependence.
        let accept = rng.f64() * rate_max < rate_at(t);
        let tenant = sample_tenant(rng, cum);
        if accept {
            out.push(Arrival { t, tenant, id: out.len() as u64, batch: 1 });
        }
        t += exp_variate(rng, rate_max);
    }
}

/// Generate the arrival stream for a spec over a tenant set, sorted by
/// time with sequential ids.  Panics (via [`TrafficSpec::validate`])
/// on malformed specs; zero-rate processes/segments yield no arrivals.
pub fn generate(spec: &TrafficSpec, tenants: &[Tenant]) -> Vec<Arrival> {
    assert!(!tenants.is_empty(), "traffic needs at least one tenant");
    spec.validate();
    let mut rng = XorShift::new(spec.seed);
    let cum: Vec<f64> = tenants
        .iter()
        .scan(0.0, |acc, t| {
            *acc += t.weight;
            Some(*acc)
        })
        .collect();
    let mut out = Vec::new();
    match &spec.process {
        ArrivalProcess::Poisson { qps } => {
            if *qps > 0.0 {
                let mut t = exp_variate(&mut rng, *qps);
                while t < spec.duration_s {
                    let tenant = sample_tenant(&mut rng, &cum);
                    out.push(Arrival { t, tenant, id: out.len() as u64, batch: 1 });
                    t += exp_variate(&mut rng, *qps);
                }
            }
        }
        ArrivalProcess::Bursty { base_qps, burst_qps, mean_burst_s, mean_quiet_s } => {
            let mut in_burst = false;
            let mut t = 0.0f64;
            let mut state_end = exp_variate(&mut rng, 1.0 / mean_quiet_s);
            while t < spec.duration_s {
                let rate = if in_burst { *burst_qps } else { *base_qps };
                // A zero-rate state emits nothing: skip straight to the
                // state boundary (previously ∞ via exp_variate(0)).
                let dt = if rate > 0.0 { exp_variate(&mut rng, rate) } else { f64::INFINITY };
                if t + dt >= state_end {
                    // The exponential is memoryless: jumping to the state
                    // boundary and redrawing preserves the process law.
                    t = state_end;
                    in_burst = !in_burst;
                    let mean = if in_burst { *mean_burst_s } else { *mean_quiet_s };
                    state_end = t + exp_variate(&mut rng, 1.0 / mean);
                    continue;
                }
                t += dt;
                if t >= spec.duration_s {
                    break;
                }
                let tenant = sample_tenant(&mut rng, &cum);
                out.push(Arrival { t, tenant, id: out.len() as u64, batch: 1 });
            }
        }
        ArrivalProcess::Diurnal { base_qps, amplitude, period_s } => {
            let (base, amp, period) = (*base_qps, *amplitude, *period_s);
            thinned(
                &mut rng,
                base * (1.0 + amp),
                |t| base * (1.0 + amp * (std::f64::consts::TAU * t / period).sin()),
                spec.duration_s,
                &cum,
                &mut out,
            );
        }
        ArrivalProcess::FlashCrowd { base_qps, spike_qps, t_spike, spike_s } => {
            let (base, spike, t0, width) = (*base_qps, *spike_qps, *t_spike, *spike_s);
            thinned(
                &mut rng,
                base + spike,
                |t| if t >= t0 && t < t0 + width { base + spike } else { base },
                spec.duration_s,
                &cum,
                &mut out,
            );
        }
        ArrivalProcess::Trace(trace) => {
            let mut sorted: Vec<Arrival> = trace
                .iter()
                .filter(|a| a.t < spec.duration_s)
                .copied()
                .collect();
            sorted.sort_by(|a, b| a.t.total_cmp(&b.t).then(a.id.cmp(&b.id)));
            for (i, a) in sorted.iter_mut().enumerate() {
                assert!(a.tenant < tenants.len(), "trace tenant out of range");
                a.id = i as u64;
                a.batch = a.batch.max(1);
            }
            out = sorted;
        }
    }
    debug_assert!(out.windows(2).all(|w| w[0].t <= w[1].t));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::ModelGraph;

    fn toy_tenants(n: usize) -> Vec<Tenant> {
        (0..n)
            .map(|i| {
                let mut g = ModelGraph::new(format!("toy{i}"));
                g.add("fc", 64, 64, 64, vec![]);
                Tenant::new(g, 1.0)
            })
            .collect()
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let tenants = toy_tenants(1);
        let spec = TrafficSpec::poisson(1000.0, 4.0, 7);
        let a = generate(&spec, &tenants);
        // ~4000 expected; 5 sigma ≈ 316.
        assert!((a.len() as i64 - 4000).abs() < 400, "got {}", a.len());
        assert!(a.iter().all(|x| x.t < 4.0 && x.batch == 1));
        assert!(a.windows(2).all(|w| w[0].t <= w[1].t && w[0].id < w[1].id));
    }

    #[test]
    fn same_seed_same_trace_different_seed_differs() {
        let tenants = toy_tenants(2);
        let spec = TrafficSpec::poisson(500.0, 1.0, 42);
        let a = generate(&spec, &tenants);
        let b = generate(&spec, &tenants);
        assert_eq!(a, b);
        let other = generate(&TrafficSpec::poisson(500.0, 1.0, 43), &tenants);
        assert_ne!(a, other);
    }

    #[test]
    fn tenant_mix_follows_weights() {
        let mut tenants = toy_tenants(2);
        tenants[0].weight = 3.0;
        let spec = TrafficSpec::poisson(2000.0, 2.0, 11);
        let a = generate(&spec, &tenants);
        let first = a.iter().filter(|x| x.tenant == 0).count();
        let frac = first as f64 / a.len() as f64;
        assert!((frac - 0.75).abs() < 0.05, "tenant-0 share {frac}");
    }

    #[test]
    fn bursty_has_higher_peak_density_than_poisson() {
        let tenants = toy_tenants(1);
        let spec = TrafficSpec::bursty(100.0, 4000.0, 0.05, 0.2, 4.0, 3);
        let a = generate(&spec, &tenants);
        assert!(!a.is_empty());
        // Count arrivals per 50 ms bin; the busiest bin must far exceed
        // the mean bin (burstiness), which a flat Poisson would not.
        let bins = (4.0 / 0.05) as usize;
        let mut hist = vec![0usize; bins];
        for x in &a {
            hist[((x.t / 0.05) as usize).min(bins - 1)] += 1;
        }
        let max = *hist.iter().max().unwrap();
        let mean = a.len() as f64 / bins as f64;
        assert!(max as f64 > 3.0 * mean, "max {max} mean {mean:.1}");
    }

    /// Inter-arrival gaps of a time-sorted stream.
    fn gaps(a: &[Arrival]) -> Vec<f64> {
        let mut out = Vec::with_capacity(a.len().saturating_sub(1));
        for w in a.windows(2) {
            out.push(w[1].t - w[0].t);
        }
        out
    }

    /// Squared coefficient of variation (variance / mean²) — the
    /// burstiness index: 1 for a Poisson process, > 1 for MMPP.
    fn cv2(xs: &[f64]) -> f64 {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        var / (mean * mean)
    }

    #[test]
    fn poisson_empirical_mean_within_tolerance() {
        // Mean inter-arrival of a 1000 req/s Poisson stream is 1 ms;
        // over ~8000 samples the empirical mean must land within 5%
        // (the seed is fixed, so this is a deterministic check, but the
        // tolerance documents the statistical contract).
        let tenants = toy_tenants(1);
        let a = generate(&TrafficSpec::poisson(1000.0, 8.0, 13), &tenants);
        assert!(a.len() > 6000, "got {}", a.len());
        let g = gaps(&a);
        let mean = g.iter().sum::<f64>() / g.len() as f64;
        assert!(
            (mean - 1e-3).abs() < 1e-4,
            "empirical mean inter-arrival {mean:.6} s vs expected 0.001 s"
        );
        // And the gap distribution is memoryless-shaped: CV² ≈ 1.
        let c = cv2(&g);
        assert!((0.85..1.15).contains(&c), "Poisson CV² {c:.3}");
    }

    #[test]
    fn mmpp_burstiness_exceeds_poisson() {
        // A two-state MMPP with a 40× rate ratio must show markedly
        // over-dispersed inter-arrivals relative to a Poisson stream of
        // any rate (CV² well above 1).
        let tenants = toy_tenants(1);
        let mmpp = generate(
            &TrafficSpec::bursty(100.0, 4000.0, 0.05, 0.2, 8.0, 17),
            &tenants,
        );
        let poisson = generate(&TrafficSpec::poisson(1000.0, 8.0, 17), &tenants);
        assert!(mmpp.len() > 1000 && poisson.len() > 1000);
        let (cb, cp) = (cv2(&gaps(&mmpp)), cv2(&gaps(&poisson)));
        assert!(cp < 1.2, "Poisson CV² {cp:.3}");
        assert!(cb > 2.0, "MMPP CV² {cb:.3} not bursty");
        assert!(cb > 1.5 * cp, "MMPP CV² {cb:.3} vs Poisson {cp:.3}");
    }

    #[test]
    fn trace_replay_is_byte_exact() {
        // Replaying an already-sorted, already-indexed stream through
        // the Trace process reproduces it exactly — every field.
        let tenants = toy_tenants(2);
        let original = generate(&TrafficSpec::poisson(500.0, 1.0, 23), &tenants);
        assert!(!original.is_empty());
        let replayed = generate(
            &TrafficSpec {
                process: ArrivalProcess::Trace(original.clone()),
                duration_s: 1.0,
                seed: 99, // the seed must not matter for replay
            },
            &tenants,
        );
        assert_eq!(original, replayed);
        // A second replay of the replay is still exact (idempotent).
        let again = generate(
            &TrafficSpec {
                process: ArrivalProcess::Trace(replayed.clone()),
                duration_s: 1.0,
                seed: 7,
            },
            &tenants,
        );
        assert_eq!(replayed, again);
    }

    #[test]
    fn all_generators_deterministic_across_seeds() {
        // Equal seeds reproduce byte-identical streams and different
        // seeds differ, for every process shape.
        let tenants = toy_tenants(2);
        let check = |mk: &dyn Fn(u64) -> TrafficSpec| {
            let a = generate(&mk(5), &tenants);
            let b = generate(&mk(5), &tenants);
            let c = generate(&mk(6), &tenants);
            assert_eq!(a, b, "same seed must reproduce the stream");
            assert_ne!(a, c, "different seeds must differ");
        };
        check(&|s| TrafficSpec::poisson(800.0, 0.5, s));
        check(&|s| TrafficSpec::bursty(200.0, 2000.0, 0.02, 0.1, 0.5, s));
        check(&|s| TrafficSpec::diurnal(1500.0, 0.9, 0.25, 0.5, s));
        check(&|s| TrafficSpec::flash_crowd(400.0, 4000.0, 0.2, 0.1, 0.5, s));
        // Trace replay is seed-independent by construction.
        let base = generate(&TrafficSpec::poisson(800.0, 0.5, 5), &tenants);
        let t1 = generate(
            &TrafficSpec {
                process: ArrivalProcess::Trace(base.clone()),
                duration_s: 0.5,
                seed: 1,
            },
            &tenants,
        );
        let t2 = generate(
            &TrafficSpec {
                process: ArrivalProcess::Trace(base),
                duration_s: 0.5,
                seed: 2,
            },
            &tenants,
        );
        assert_eq!(t1, t2);
    }

    #[test]
    fn rate_zero_specs_emit_no_arrivals_in_any_profile() {
        // Regression: `poisson(0.0, ..)` used to abort at generation
        // (and, without that assert, exp_variate would divide by zero
        // to ∞ timestamps in release).  A zero rate now means "no
        // traffic" — required for diurnal troughs and drained
        // autoscaler segments.  This test runs in both debug and
        // release CI profiles.
        let tenants = toy_tenants(1);
        assert!(generate(&TrafficSpec::poisson(0.0, 1.0, 3), &tenants).is_empty());
        assert!(
            generate(&TrafficSpec::diurnal(0.0, 1.0, 0.5, 1.0, 3), &tenants).is_empty()
        );
        assert!(
            generate(&TrafficSpec::flash_crowd(0.0, 0.0, 0.1, 0.2, 1.0, 3), &tenants)
                .is_empty()
        );
        // A zero-rate *segment*: bursty with a silent quiet state still
        // produces finite, in-horizon timestamps from the burst state.
        let a = generate(&TrafficSpec::bursty(0.0, 2000.0, 0.05, 0.05, 1.0, 3), &tenants);
        assert!(!a.is_empty(), "burst state must still emit");
        assert!(a.iter().all(|x| x.t.is_finite() && x.t < 1.0));
    }

    #[test]
    #[should_panic(expected = "finite rate >= 0")]
    fn negative_rate_rejected_at_construction() {
        TrafficSpec::poisson(-1.0, 1.0, 0);
    }

    #[test]
    #[should_panic(expected = "finite rate >= 0")]
    fn non_finite_rate_rejected_even_as_struct_literal() {
        // generate() re-validates, so literal construction cannot
        // bypass the constructor checks.
        let spec = TrafficSpec {
            process: ArrivalProcess::Poisson { qps: f64::INFINITY },
            duration_s: 1.0,
            seed: 0,
        };
        generate(&spec, &toy_tenants(1));
    }

    #[test]
    #[should_panic(expected = "amplitude must lie in [0, 1]")]
    fn diurnal_amplitude_out_of_range_rejected() {
        TrafficSpec::diurnal(100.0, 1.5, 1.0, 1.0, 0);
    }

    #[test]
    fn diurnal_peak_density_exceeds_trough() {
        // One full cycle at amplitude 0.9: the quarter-cycle around the
        // sinusoid peak must carry far more arrivals than the one
        // around the trough (rate ratio 19:1).
        let tenants = toy_tenants(1);
        let period = 4.0;
        let a = generate(&TrafficSpec::diurnal(2000.0, 0.9, period, period, 29), &tenants);
        assert!(a.len() > 2000, "got {}", a.len());
        // Peak at t = period/4, trough at t = 3·period/4.
        let around = |center: f64| {
            a.iter()
                .filter(|x| (x.t - center).abs() < period / 8.0)
                .count() as f64
        };
        let (peak, trough) = (around(period / 4.0), around(3.0 * period / 4.0));
        assert!(
            peak > 4.0 * (trough + 1.0),
            "peak bin {peak} vs trough bin {trough}"
        );
    }

    #[test]
    fn flash_crowd_spikes_only_inside_the_window() {
        let tenants = toy_tenants(1);
        let a = generate(
            &TrafficSpec::flash_crowd(500.0, 9500.0, 0.4, 0.2, 1.0, 31),
            &tenants,
        );
        let inside = a.iter().filter(|x| x.t >= 0.4 && x.t < 0.6).count() as f64;
        let outside = a.len() as f64 - inside;
        // 20× the rate over 20% of the horizon: the window holds the
        // clear majority of arrivals.
        assert!(inside > 2.0 * outside, "inside {inside} outside {outside}");
        // Outside density stays near the 500 req/s floor.
        assert!(outside > 100.0 && outside < 800.0, "outside {outside}");
    }

    #[test]
    fn trace_replay_clamps_sorts_and_reindexes() {
        let tenants = toy_tenants(2);
        let trace = vec![
            Arrival { t: 0.9, tenant: 1, id: 99, batch: 0 },
            Arrival { t: 0.1, tenant: 0, id: 98, batch: 4 },
            Arrival { t: 5.0, tenant: 0, id: 97, batch: 1 },
        ];
        let spec = TrafficSpec {
            process: ArrivalProcess::Trace(trace),
            duration_s: 1.0,
            seed: 0,
        };
        let a = generate(&spec, &tenants);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].t, 0.1);
        assert_eq!(a[0].id, 0);
        assert_eq!(a[0].batch, 4);
        assert_eq!(a[1].t, 0.9);
        assert_eq!(a[1].batch, 1, "batch 0 normalized to 1");
    }
}
