//! Autoregressive serving: prefill–decode split, KV-cache capacity,
//! and continuous batching over the cycle-level cost model.
//!
//! # Request lifecycle
//!
//! ```text
//!  arrival          join (prefill)        decode steps           leave
//!  ───────── wait ─ ───────────────── ─ ────────────────────── ─ ─────
//!  t_arrival        prefill_tokens       one token / iteration   last
//!                   processed in one     ctx grows by 1, KV      token;
//!                   batched pass; the    cache grows by          KV
//!                   pass emits the       bytes_per_token         state
//!                   FIRST token (TTFT)                           freed
//! ```
//!
//! Each request is `(prefill_tokens, decode_steps)`: the prompt pass
//! runs every GEMM at the full context length and produces the first
//! token (its completion time defines **TTFT**, time-to-first-token);
//! each subsequent decode iteration runs the incremental single-token
//! graph and produces one more token (**TPOT**, time-per-output-token,
//! is the mean inter-token gap over the decode phase).  Phase GEMMs
//! come from [`DecoderSpec::prefill`] / [`DecoderSpec::decode`], so
//! both phases price through the same compile → schedule → execute
//! pipeline as every other workload.
//!
//! # Scheduling policies
//!
//! * **Continuous** ([`AutoregPolicy::Continuous`]) — iteration-level
//!   scheduling: between any two decode iterations, newly arrived
//!   requests join the running batch (their prefill is folded into the
//!   iteration) and finished requests leave immediately, freeing their
//!   KV state and batch slot.  The batch size breathes with the load.
//! * **Static** ([`AutoregPolicy::Static`]) — the classic max-batch +
//!   max-wait policy of [`crate::serve::engine`] applied to whole
//!   requests: a batch forms, prefills together, then decodes with
//!   every slot held until the *longest* member finishes.  Arrivals
//!   during a batch wait for the next one.  This is the A/B baseline
//!   continuous batching is measured against.
//!
//! # KV-cache admission
//!
//! Live K/V state is modelled by [`KvModel`]: every prefilled or
//! generated token appends `bytes_per_token` and the node's aggregate
//! SRAM bounds the total.  Admission is **reserved** by default — a
//! request joins only if its *final* footprint (`prefill + decode`
//! tokens) fits beside the reservations of every active request, so
//! eviction is impossible.  With [`AutoregConfig::optimistic`] a
//! request joins if it fits *now*; when growth later overflows the
//! capacity the youngest request is evicted ([`Event::KvEvict`]),
//! re-queued, and pays a fresh prefill over everything it had.
//!
//! # Cost model and determinism
//!
//! [`DecodeCostCache`] memoizes the simulated seconds of each distinct
//! `(phase, context bucket, batch)` composition — context lengths are
//! quantized to [`AutoregConfig::ctx_bucket`] so a million-token trace
//! compiles a handful of graphs.  The engine itself is a sequential
//! discrete-event loop: runs are bit-identical for any `SOSA_THREADS`,
//! warm or cold cache (property-pinned in the tests below).

// Event fields are u32 by trace-format choice; values are bounded by
// the batch size.  lint:allow(cast, file)

use std::collections::{BTreeMap, HashMap, VecDeque};

use crate::arch::ArchConfig;
use crate::error::Result;
use crate::obs::{Event, NullSink, TraceSink};
use crate::sim::memory::KvModel;
use crate::sim::{SimContext, SimOptions, SweepExecutor};
use crate::testutil::XorShift;
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::extra::DecoderSpec;

/// One autoregressive request: a prompt of `prefill_tokens` followed
/// by `decode_steps` generated tokens (the first of which is produced
/// by the prefill pass itself).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecodeRequest {
    pub id: u64,
    /// Arrival time, seconds.
    pub t_arrival: f64,
    /// Prompt length, tokens (>= 1).
    pub prefill_tokens: usize,
    /// Tokens to generate (>= 1).
    pub decode_steps: usize,
}

/// Batch scheduling policy for the autoregressive engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoregPolicy {
    /// Iteration-level scheduling: join/leave between decode steps.
    Continuous,
    /// Whole-request batches: max-batch + max-wait formation, every
    /// slot held until the longest member finishes.
    Static,
}

impl AutoregPolicy {
    /// Stable report name.
    pub fn name(self) -> &'static str {
        match self {
            AutoregPolicy::Continuous => "continuous",
            AutoregPolicy::Static => "static",
        }
    }
}

/// Autoregressive engine configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct AutoregConfig {
    pub policy: AutoregPolicy,
    /// Batch slots (concurrent requests in the running batch).
    pub max_batch: usize,
    /// Static policy only: head-of-line batch-formation wait.
    pub max_wait_s: f64,
    /// Context-length quantum for the cost cache: phase costs are
    /// priced at the context rounded up to this multiple, bounding the
    /// number of distinct compilations while keeping cost growth with
    /// KV length.
    pub ctx_bucket: usize,
    /// Admit on the *current* KV footprint instead of the final one;
    /// overflow later evicts the youngest request (continuous only —
    /// static batches always reserve their final footprint).
    pub optimistic: bool,
    /// Cost-model options (shared with the whole sim stack).
    pub sim: SimOptions,
}

impl Default for AutoregConfig {
    fn default() -> Self {
        AutoregConfig {
            policy: AutoregPolicy::Continuous,
            max_batch: 8,
            max_wait_s: 2e-3,
            ctx_bucket: 64,
            optimistic: false,
            sim: SimOptions::default(),
        }
    }
}

/// Memoized phase costs: simulated seconds of each distinct
/// `(phase, context bucket, batch)` composition, compiled once on a
/// pooled [`SimContext`] (with `sim.pooling` off it rebuilds per miss
/// — the cold A/B baseline; results are bit-identical either way).
#[derive(Debug)]
pub struct DecodeCostCache {
    cfg: ArchConfig,
    spec: DecoderSpec,
    opts: SimOptions,
    bucket: usize,
    map: HashMap<(bool, usize, usize), f64>,
    ctx: SimContext,
    /// Simulator (execute-phase) invocations so far.
    pub sim_calls: u64,
    /// Compile-phase invocations so far.
    pub compile_calls: u64,
}

impl DecodeCostCache {
    /// New cache for a decoder family on a configuration.
    pub fn new(cfg: ArchConfig, spec: DecoderSpec, opts: SimOptions, ctx_bucket: usize) -> Self {
        DecodeCostCache {
            cfg,
            spec,
            opts,
            bucket: ctx_bucket.max(1),
            map: HashMap::new(),
            ctx: SimContext::new(),
            sim_calls: 0,
            compile_calls: 0,
        }
    }

    /// The configuration the cache prices against.
    pub fn cfg(&self) -> &ArchConfig {
        &self.cfg
    }

    /// The decoder family the cache prices.
    pub fn spec(&self) -> &DecoderSpec {
        &self.spec
    }

    /// Distinct compositions priced so far.
    pub fn entries(&self) -> usize {
        self.map.len()
    }

    /// `tokens` rounded up to the cache's context quantum.
    pub fn bucketed(&self, tokens: usize) -> usize {
        tokens.max(1).div_ceil(self.bucket) * self.bucket
    }

    /// Seconds for a batched prefill pass over `tokens` of context.
    pub fn prefill_seconds(&mut self, tokens: usize, batch: usize) -> f64 {
        let b = self.bucketed(tokens);
        self.seconds(false, b, batch)
    }

    /// Seconds for one batched decode iteration at `ctx_tokens` of
    /// cached context.
    pub fn decode_seconds(&mut self, ctx_tokens: usize, batch: usize) -> f64 {
        let b = self.bucketed(ctx_tokens);
        self.seconds(true, b, batch)
    }

    fn seconds(&mut self, decode: bool, tokens: usize, batch: usize) -> f64 {
        let key = (decode, tokens, batch);
        if let Some(&s) = self.map.get(&key) {
            return s;
        }
        if !self.opts.pooling {
            // Cold A/B baseline: rebuild scheduler state per miss.
            self.ctx = SimContext::new();
        }
        let graph = if decode { self.spec.decode(tokens) } else { self.spec.prefill(tokens) };
        let graph = graph.with_batch(batch.max(1));
        let refs = [&graph];
        let cp = crate::compile::compile_multi_with(&mut self.ctx, &self.cfg, &refs, &self.opts);
        self.compile_calls += 1;
        let stats = cp.execute_with(&mut self.ctx, &self.cfg, &self.opts);
        self.sim_calls += 1;
        let s = stats.exec_seconds(&self.cfg);
        self.map.insert(key, s);
        s
    }
}

/// One completed request, with its token-timing milestones.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServedDecode {
    pub id: u64,
    pub t_arrival: f64,
    /// When the first token came out (end of the prefill iteration).
    pub t_first_token: f64,
    /// When the last token came out.
    pub t_end: f64,
    pub prefill_tokens: usize,
    pub decode_steps: usize,
    /// Times this request was KV-evicted and re-prefilled.
    pub evictions: u32,
}

impl ServedDecode {
    /// Time-to-first-token: arrival → first token.
    pub fn ttft_s(&self) -> f64 {
        self.t_first_token - self.t_arrival
    }

    /// Time-per-output-token: mean inter-token gap over the decode
    /// phase (0 for single-token requests — there is no gap).
    pub fn tpot_s(&self) -> f64 {
        if self.decode_steps <= 1 {
            return 0.0;
        }
        (self.t_end - self.t_first_token) / (self.decode_steps - 1) as f64
    }
}

/// Result of one autoregressive serving run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AutoregReport {
    /// Completed requests, in completion order.
    pub completed: Vec<ServedDecode>,
    /// Requests whose KV state alone exceeds the node's SRAM — never
    /// admissible, shed at the head of the queue.
    pub rejected: u64,
    /// Engine iterations (each a prefill group and/or a decode step).
    pub iterations: u64,
    /// Prefill passes, counting re-prefills after eviction.
    pub prefills: u64,
    /// KV evictions (optimistic admission only).
    pub evictions: u64,
    /// Tokens generated across all requests.
    pub generated_tokens: u64,
    /// Peak live KV bytes across the run.
    pub peak_kv_bytes: u64,
    /// Peak running-batch size.
    pub peak_batch: usize,
    /// End of the last iteration, seconds.
    pub makespan_s: f64,
    /// Accelerator-busy seconds (sum of iteration costs).
    pub busy_s: f64,
    /// Simulator invocations this run (cache-miss count).
    pub sim_calls: u64,
    /// Compile invocations this run.
    pub compile_calls: u64,
}

impl AutoregReport {
    /// Busy fraction over the makespan.
    pub fn busy_frac(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.busy_s / self.makespan_s
        } else {
            0.0
        }
    }

    /// Merge per-node reports into a fleet view: completions are
    /// re-sorted by `(t_end, id)`, makespan is the slowest node, busy
    /// seconds add (divide by node count for a fleet busy fraction),
    /// peaks take the max.
    pub fn merge(reports: Vec<AutoregReport>) -> AutoregReport {
        let mut out = AutoregReport::default();
        for r in reports {
            out.completed.extend(r.completed);
            out.rejected += r.rejected;
            out.iterations += r.iterations;
            out.prefills += r.prefills;
            out.evictions += r.evictions;
            out.generated_tokens += r.generated_tokens;
            out.peak_kv_bytes = out.peak_kv_bytes.max(r.peak_kv_bytes);
            out.peak_batch = out.peak_batch.max(r.peak_batch);
            out.makespan_s = out.makespan_s.max(r.makespan_s);
            out.busy_s += r.busy_s;
            out.sim_calls += r.sim_calls;
            out.compile_calls += r.compile_calls;
        }
        out.completed.sort_by(|a, b| a.t_end.total_cmp(&b.t_end).then(a.id.cmp(&b.id)));
        out
    }
}

/// A request in the running batch.
#[derive(Clone, Copy, Debug)]
struct Active {
    id: u64,
    t_arrival: f64,
    prefill_tokens: usize,
    decode_steps: usize,
    /// Tokens generated so far (also: KV tokens beyond the prompt).
    generated: usize,
    t_first: Option<f64>,
    evictions: u32,
}

impl Active {
    /// Live KV tokens (prompt + generated).
    fn kv_tokens(&self) -> usize {
        self.prefill_tokens + self.generated
    }
}

/// A request waiting to (re)join: `generated > 0` means it was evicted
/// and must re-prefill everything it had.
#[derive(Clone, Copy, Debug)]
struct Waiting {
    req: DecodeRequest,
    generated: usize,
    t_first: Option<f64>,
    evictions: u32,
}

impl Waiting {
    fn fresh(req: DecodeRequest) -> Waiting {
        Waiting { req, generated: 0, t_first: None, evictions: 0 }
    }

    /// Tokens a (re)join's prefill pass must process.
    fn restore_tokens(&self) -> usize {
        self.req.prefill_tokens + self.generated
    }
}

/// The autoregressive serving engine: a sequential discrete-event loop
/// over [`DecodeRequest`]s, deterministic for any thread count.
#[derive(Debug)]
pub struct AutoregEngine {
    acfg: AutoregConfig,
    kv: KvModel,
    cache: DecodeCostCache,
}

impl AutoregEngine {
    /// New engine (fresh cost cache) for a decoder on a configuration.
    pub fn new(cfg: &ArchConfig, spec: &DecoderSpec, acfg: AutoregConfig) -> Self {
        let cache =
            DecodeCostCache::new(cfg.clone(), spec.clone(), acfg.sim.clone(), acfg.ctx_bucket);
        AutoregEngine::from_cache(cache, acfg)
    }

    /// Engine over a pre-warmed cache (e.g. from a previous run via
    /// [`AutoregEngine::into_cache`]).  The cache's configuration,
    /// decoder and sim options are authoritative and must match.
    pub fn from_cache(cache: DecodeCostCache, acfg: AutoregConfig) -> Self {
        assert_eq!(cache.opts, acfg.sim, "cache was built with different sim options");
        assert_eq!(cache.bucket, acfg.ctx_bucket.max(1), "cache uses a different ctx bucket");
        let kv = KvModel::for_decoder(&cache.cfg, &cache.spec);
        AutoregEngine { acfg, kv, cache }
    }

    /// Surrender the warmed cost cache for reuse.
    pub fn into_cache(self) -> DecodeCostCache {
        self.cache
    }

    /// The engine's KV-cache model.
    pub fn kv(&self) -> KvModel {
        self.kv
    }

    /// Run a request trace without tracing.
    pub fn run(&mut self, requests: &[DecodeRequest]) -> AutoregReport {
        let mut sink = NullSink;
        self.run_traced(requests, &mut sink)
    }

    /// Run a request trace, emitting [`Event::DecodeStep`] /
    /// [`Event::RequestJoin`] / [`Event::RequestLeave`] /
    /// [`Event::KvEvict`] into `sink`.
    pub fn run_traced(
        &mut self,
        requests: &[DecodeRequest],
        sink: &mut dyn TraceSink,
    ) -> AutoregReport {
        let mut sorted = requests.to_vec();
        sorted.sort_by(|a, b| a.t_arrival.total_cmp(&b.t_arrival).then(a.id.cmp(&b.id)));
        let sim_calls0 = self.cache.sim_calls;
        let compile_calls0 = self.cache.compile_calls;
        let mut rep = match self.acfg.policy {
            AutoregPolicy::Continuous => self.run_continuous(&sorted, sink),
            AutoregPolicy::Static => self.run_static(&sorted, sink),
        };
        rep.sim_calls = self.cache.sim_calls - sim_calls0;
        rep.compile_calls = self.cache.compile_calls - compile_calls0;
        rep
    }

    /// Estimated steady-state request throughput at the mean request
    /// shape: the largest admissible batch amortizing one prefill and
    /// `decode_steps - 1` decode iterations per request.
    pub fn capacity_qps(&mut self, prefill_tokens: usize, decode_steps: usize) -> f64 {
        let tokens = (prefill_tokens + decode_steps) as u64;
        let b = self.acfg.max_batch.min(self.kv.max_batch(&self.cache.cfg, tokens)).max(1);
        let per = self.cache.prefill_seconds(prefill_tokens, b)
            + decode_steps.saturating_sub(1) as f64
                * self.cache.decode_seconds(prefill_tokens + decode_steps, b);
        if per > 0.0 {
            b as f64 / per
        } else {
            0.0
        }
    }

    /// Final-footprint KV tokens a request needs end to end.
    fn final_tokens(r: &DecodeRequest) -> u64 {
        (r.prefill_tokens + r.decode_steps) as u64
    }

    fn run_continuous(
        &mut self,
        sorted: &[DecodeRequest],
        sink: &mut dyn TraceSink,
    ) -> AutoregReport {
        let cap = self.kv.capacity_tokens(&self.cache.cfg);
        let mut pending: VecDeque<Waiting> = sorted.iter().map(|&r| Waiting::fresh(r)).collect();
        let mut active: Vec<Active> = Vec::new();
        let mut rep = AutoregReport::default();
        let mut t = 0.0f64;
        let mut iter: u64 = 0;
        loop {
            if active.is_empty() {
                match pending.front() {
                    None => break,
                    Some(w) => {
                        if w.req.t_arrival > t {
                            t = w.req.t_arrival;
                        }
                    }
                }
            }
            // Admission: FIFO over arrived requests, bounded by batch
            // slots and KV capacity (reserved: final footprint;
            // optimistic: current footprint).
            let mut reserved: u64 = 0;
            for a in &active {
                reserved += if self.acfg.optimistic {
                    a.kv_tokens() as u64
                } else {
                    (a.prefill_tokens + a.decode_steps) as u64
                };
            }
            let mut joiners: Vec<Active> = Vec::new();
            while active.len() + joiners.len() < self.acfg.max_batch {
                let Some(w) = pending.front() else { break };
                if w.req.t_arrival > t {
                    break;
                }
                let need = if self.acfg.optimistic {
                    (w.restore_tokens() + 1) as u64
                } else {
                    Self::final_tokens(&w.req).max((w.restore_tokens() + 1) as u64)
                };
                if need > cap {
                    // Unservable even alone: KV exceeds node SRAM.
                    pending.pop_front().expect("front checked");
                    rep.rejected += 1;
                    continue;
                }
                if reserved + need > cap {
                    break;
                }
                reserved += need;
                let w = pending.pop_front().expect("front checked");
                joiners.push(Active {
                    id: w.req.id,
                    t_arrival: w.req.t_arrival,
                    prefill_tokens: w.req.prefill_tokens,
                    decode_steps: w.req.decode_steps,
                    generated: w.generated,
                    t_first: w.t_first,
                    evictions: w.evictions,
                });
            }
            if active.is_empty() && joiners.is_empty() {
                if pending.is_empty() {
                    break;
                }
                continue; // head not yet arrived or KV-blocked; re-time.
            }
            // One iteration: joiners prefill (grouped by context
            // bucket), previously-active requests run one decode step.
            let t_start = t;
            let old_n = active.len();
            let mut dt = 0.0f64;
            let mut groups: BTreeMap<usize, usize> = BTreeMap::new();
            for j in &joiners {
                let restore = j.prefill_tokens + j.generated;
                *groups.entry(self.cache.bucketed(restore)).or_insert(0) += 1;
            }
            for (&bucket, &count) in &groups {
                dt += self.cache.prefill_seconds(bucket, count);
                rep.prefills += count as u64;
            }
            if old_n > 0 {
                let max_ctx =
                    active.iter().map(Active::kv_tokens).max().expect("old_n > 0");
                dt += self.cache.decode_seconds(max_ctx, old_n);
            }
            t = t_start + dt;
            rep.busy_s += dt;
            // Every participant produced one token this iteration:
            // actives from the decode step, joiners from the prefill.
            for a in active.iter_mut() {
                a.generated += 1;
            }
            for j in joiners.iter_mut() {
                j.generated += 1;
                if j.t_first.is_none() {
                    j.t_first = Some(t);
                }
            }
            if sink.enabled() {
                for j in &joiners {
                    sink.event(Event::RequestJoin { id: j.id, t });
                }
            }
            active.extend(joiners);
            let batch = active.len();
            rep.generated_tokens += batch as u64;
            let live: u64 = active.iter().map(|a| a.kv_tokens() as u64).sum();
            rep.peak_kv_bytes = rep.peak_kv_bytes.max(self.kv.footprint_bytes(live));
            rep.peak_batch = rep.peak_batch.max(batch);
            if sink.enabled() {
                sink.event(Event::DecodeStep {
                    iter,
                    t_start,
                    t_end: t,
                    batch: batch as u32,
                    kv_tokens: live,
                });
            }
            iter += 1;
            rep.iterations += 1;
            // Leave: finished requests release their slot and KV.
            let mut still = Vec::with_capacity(active.len());
            for a in active.drain(..) {
                if a.generated >= a.decode_steps {
                    if sink.enabled() {
                        sink.event(Event::RequestLeave { id: a.id, t });
                    }
                    rep.completed.push(ServedDecode {
                        id: a.id,
                        t_arrival: a.t_arrival,
                        t_first_token: a.t_first.expect("completed ⇒ produced a token"),
                        t_end: t,
                        prefill_tokens: a.prefill_tokens,
                        decode_steps: a.decode_steps,
                        evictions: a.evictions,
                    });
                } else {
                    still.push(a);
                }
            }
            active = still;
            // Optimistic overflow: evict youngest until the cache fits.
            if self.acfg.optimistic {
                let mut live: u64 = active.iter().map(|a| a.kv_tokens() as u64).sum();
                while live > cap {
                    let v = active.pop().expect("live > 0 ⇒ non-empty");
                    let tokens = v.kv_tokens() as u64;
                    live -= tokens;
                    rep.evictions += 1;
                    if sink.enabled() {
                        sink.event(Event::KvEvict {
                            id: v.id,
                            t,
                            kv_bytes: self.kv.footprint_bytes(tokens),
                        });
                    }
                    pending.push_front(Waiting {
                        req: DecodeRequest {
                            id: v.id,
                            t_arrival: v.t_arrival,
                            prefill_tokens: v.prefill_tokens,
                            decode_steps: v.decode_steps,
                        },
                        generated: v.generated,
                        t_first: v.t_first,
                        evictions: v.evictions + 1,
                    });
                }
            }
        }
        rep.makespan_s = t;
        rep
    }

    fn run_static(&mut self, sorted: &[DecodeRequest], sink: &mut dyn TraceSink) -> AutoregReport {
        let cap = self.kv.capacity_tokens(&self.cache.cfg);
        let mut pending: VecDeque<DecodeRequest> = sorted.iter().copied().collect();
        let mut rep = AutoregReport::default();
        let mut t = 0.0f64; // machine-free time
        let mut iter: u64 = 0;
        while let Some(&head) = pending.front() {
            if Self::final_tokens(&head) > cap {
                pending.pop_front();
                rep.rejected += 1;
                continue;
            }
            let head_t = head.t_arrival;
            let mut now = t.max(head_t);
            // Batch formation: wait for max_batch or max_wait.
            loop {
                let ready = pending.iter().take_while(|r| r.t_arrival <= now).count();
                if ready >= self.acfg.max_batch
                    || ready == pending.len()
                    || now >= head_t + self.acfg.max_wait_s
                {
                    break;
                }
                now = pending[ready].t_arrival.min(head_t + self.acfg.max_wait_s);
            }
            // Membership: FIFO over arrived requests, KV-capped by the
            // final footprint of every member (no eviction in static).
            let mut members: Vec<DecodeRequest> = Vec::new();
            let mut reserved: u64 = 0;
            while members.len() < self.acfg.max_batch {
                let Some(&r) = pending.front() else { break };
                if r.t_arrival > now {
                    break;
                }
                let need = Self::final_tokens(&r);
                if need > cap {
                    pending.pop_front();
                    rep.rejected += 1;
                    continue;
                }
                if reserved + need > cap {
                    break;
                }
                reserved += need;
                members.push(pending.pop_front().expect("front checked"));
            }
            if members.is_empty() {
                continue;
            }
            let b = members.len();
            rep.peak_batch = rep.peak_batch.max(b);
            // Phase 1: batched prefill (grouped by context bucket);
            // every member's first token appears when the pass ends.
            let t_start = now;
            let mut groups: BTreeMap<usize, usize> = BTreeMap::new();
            for m in &members {
                *groups.entry(self.cache.bucketed(m.prefill_tokens)).or_insert(0) += 1;
            }
            let mut dt = 0.0f64;
            for (&bucket, &count) in &groups {
                dt += self.cache.prefill_seconds(bucket, count);
                rep.prefills += count as u64;
            }
            let t_first = t_start + dt;
            let mut t_now = t_first;
            rep.generated_tokens += b as u64;
            let live: u64 = members.iter().map(|m| (m.prefill_tokens + 1) as u64).sum();
            rep.peak_kv_bytes = rep.peak_kv_bytes.max(self.kv.footprint_bytes(live));
            if sink.enabled() {
                for m in &members {
                    sink.event(Event::RequestJoin { id: m.id, t: t_first });
                }
                sink.event(Event::DecodeStep {
                    iter,
                    t_start,
                    t_end: t_first,
                    batch: b as u32,
                    kv_tokens: live,
                });
            }
            iter += 1;
            rep.iterations += 1;
            let finish = |r: &DecodeRequest, t_end: f64, rep: &mut AutoregReport| {
                rep.completed.push(ServedDecode {
                    id: r.id,
                    t_arrival: r.t_arrival,
                    t_first_token: t_first,
                    t_end,
                    prefill_tokens: r.prefill_tokens,
                    decode_steps: r.decode_steps,
                    evictions: 0,
                });
            };
            for m in &members {
                if m.decode_steps == 1 {
                    if sink.enabled() {
                        sink.event(Event::RequestLeave { id: m.id, t: t_first });
                    }
                    finish(m, t_first, &mut rep);
                }
            }
            // Phase 2: decode iterations at the FULL batch size —
            // finished members hold their slot and KV state until the
            // longest member drains (the static inefficiency).
            let d_max = members.iter().map(|r| r.decode_steps).max().expect("non-empty");
            for step in 2..=d_max {
                let max_ctx = members
                    .iter()
                    .map(|r| r.prefill_tokens + (step - 1).min(r.decode_steps))
                    .max()
                    .expect("non-empty");
                let sd = self.cache.decode_seconds(max_ctx, b);
                let s_start = t_now;
                t_now += sd;
                let generating =
                    members.iter().filter(|r| r.decode_steps >= step).count() as u64;
                rep.generated_tokens += generating;
                let live: u64 = members
                    .iter()
                    .map(|r| (r.prefill_tokens + step.min(r.decode_steps)) as u64)
                    .sum();
                rep.peak_kv_bytes = rep.peak_kv_bytes.max(self.kv.footprint_bytes(live));
                if sink.enabled() {
                    sink.event(Event::DecodeStep {
                        iter,
                        t_start: s_start,
                        t_end: t_now,
                        batch: b as u32,
                        kv_tokens: live,
                    });
                }
                iter += 1;
                rep.iterations += 1;
                for m in &members {
                    if m.decode_steps == step {
                        if sink.enabled() {
                            sink.event(Event::RequestLeave { id: m.id, t: t_now });
                        }
                        finish(m, t_now, &mut rep);
                    }
                }
            }
            rep.busy_s += t_now - t_start;
            t = t_now;
        }
        rep.makespan_s = t;
        rep
    }
}

/// Open-loop autoregressive traffic: Poisson arrivals with uniformly
/// distributed prompt and generation lengths, deterministic by seed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecodeTrafficSpec {
    /// Mean arrival rate, requests/second.
    pub qps: f64,
    /// Arrival horizon, seconds.
    pub duration_s: f64,
    pub seed: u64,
    /// Inclusive prompt-length range, tokens.
    pub prefill: (usize, usize),
    /// Inclusive generation-length range, tokens.
    pub decode: (usize, usize),
}

impl DecodeTrafficSpec {
    /// Poisson spec with the default request-shape ranges.
    pub fn poisson(qps: f64, duration_s: f64, seed: u64) -> Self {
        DecodeTrafficSpec { qps, duration_s, seed, prefill: (64, 256), decode: (8, 64) }
    }
}

/// Generate a seeded request trace from a traffic spec.
pub fn generate_decode(spec: &DecodeTrafficSpec) -> Vec<DecodeRequest> {
    let mut rng = XorShift::new(spec.seed);
    let mut out = Vec::new();
    if spec.qps <= 0.0 || spec.duration_s <= 0.0 {
        return out;
    }
    let (plo, phi) = (spec.prefill.0.max(1), spec.prefill.1.max(spec.prefill.0).max(1));
    let (dlo, dhi) = (spec.decode.0.max(1), spec.decode.1.max(spec.decode.0).max(1));
    let mut t = 0.0f64;
    let mut id = 0u64;
    loop {
        // Exponential inter-arrival (same variate as serve::traffic).
        t += -(1.0 - rng.f64()).ln() / spec.qps;
        if t >= spec.duration_s {
            break;
        }
        out.push(DecodeRequest {
            id,
            t_arrival: t,
            prefill_tokens: rng.range(plo, phi),
            decode_steps: rng.range(dlo, dhi),
        });
        id += 1;
    }
    out
}

/// One decode load-sweep measurement: an offered rate under one policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecodeSweepPoint {
    pub qps: f64,
    pub policy: &'static str,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_p50_s: f64,
    pub tpot_p99_s: f64,
    /// Completions meeting BOTH deadlines per second of horizon.
    pub goodput_qps: f64,
    pub completed: u64,
    pub evictions: u64,
    pub busy_frac: f64,
}

/// Decode load-sweep options.
#[derive(Clone, Debug, PartialEq)]
pub struct DecodeSweepOptions {
    /// Offered rates to measure (each runs under BOTH policies).
    pub qps: Vec<f64>,
    pub duration_s: f64,
    pub seed: u64,
    pub prefill: (usize, usize),
    pub decode: (usize, usize),
    pub ttft_deadline_s: f64,
    pub tpot_deadline_s: f64,
    /// Worker threads (None = `SOSA_THREADS` / machine default).
    pub threads: Option<usize>,
}

/// Sweep offered load × policy: each point generates the same seeded
/// trace and serves it under continuous and static batching, so the
/// two policies are compared at exactly equal offered load.  Points
/// fan across workers ([`SweepExecutor`], per-worker warm
/// [`DecodeCostCache`]) and return in item order — results are
/// bit-identical for any thread count.
pub fn decode_sweep(
    cfg: &ArchConfig,
    spec: &DecoderSpec,
    acfg: &AutoregConfig,
    sweep: &DecodeSweepOptions,
) -> Vec<DecodeSweepPoint> {
    let policies = [AutoregPolicy::Continuous, AutoregPolicy::Static];
    let items: Vec<(f64, AutoregPolicy)> =
        sweep.qps.iter().flat_map(|&q| policies.iter().map(move |&p| (q, p))).collect();
    let ex = match sweep.threads {
        Some(n) => SweepExecutor::with_threads(n),
        None => SweepExecutor::new(),
    };
    ex.run_with_state(
        &items,
        || None::<DecodeCostCache>,
        |slot, _, &(qps, policy)| {
            let cache = slot.take().unwrap_or_else(|| {
                DecodeCostCache::new(cfg.clone(), spec.clone(), acfg.sim.clone(), acfg.ctx_bucket)
            });
            let mut engine =
                AutoregEngine::from_cache(cache, AutoregConfig { policy, ..acfg.clone() });
            let requests = generate_decode(&DecodeTrafficSpec {
                qps,
                duration_s: sweep.duration_s,
                seed: sweep.seed,
                prefill: sweep.prefill,
                decode: sweep.decode,
            });
            let rep = engine.run(&requests);
            *slot = Some(engine.into_cache());
            let slo = crate::serve::slo::analyze_autoreg(
                &rep,
                sweep.duration_s,
                sweep.ttft_deadline_s,
                sweep.tpot_deadline_s,
            );
            DecodeSweepPoint {
                qps,
                policy: policy.name(),
                ttft_p50_s: slo.ttft.p50,
                ttft_p99_s: slo.ttft.p99,
                tpot_p50_s: slo.tpot.p50,
                tpot_p99_s: slo.tpot.p99,
                goodput_qps: slo.goodput_qps,
                completed: slo.completed,
                evictions: rep.evictions,
                busy_frac: slo.busy_frac,
            }
        },
    )
}

/// Write sweep points as CSV.
pub fn write_decode_sweep_csv(
    path: impl AsRef<std::path::Path>,
    points: &[DecodeSweepPoint],
) -> Result<()> {
    let mut csv = CsvWriter::create(path, &DECODE_SWEEP_COLUMNS)?;
    for p in points {
        csv.row(&decode_sweep_row(p))?;
    }
    csv.finish()
}

/// Column names shared by the CSV writer and the table renderer.
pub const DECODE_SWEEP_COLUMNS: [&str; 10] = [
    "qps",
    "policy",
    "ttft_p50_ms",
    "ttft_p99_ms",
    "tpot_p50_ms",
    "tpot_p99_ms",
    "goodput_qps",
    "completed",
    "evictions",
    "busy_pct",
];

/// One sweep point as its CSV cells (shared with the golden tests so
/// the pinned snapshot and [`write_decode_sweep_csv`] cannot drift).
pub fn decode_sweep_row(p: &DecodeSweepPoint) -> [String; 10] {
    [
        f(p.qps, 1),
        p.policy.to_string(),
        f(p.ttft_p50_s * 1e3, 3),
        f(p.ttft_p99_s * 1e3, 3),
        f(p.tpot_p50_s * 1e3, 3),
        f(p.tpot_p99_s * 1e3, 3),
        f(p.goodput_qps, 1),
        p.completed.to_string(),
        p.evictions.to_string(),
        f(100.0 * p.busy_frac, 1),
    ]
}

/// Render sweep points as the experiments' aligned table.
pub fn decode_sweep_table(points: &[DecodeSweepPoint]) -> Table {
    let mut table = Table::new(&DECODE_SWEEP_COLUMNS);
    for p in points {
        table.row(decode_sweep_row(p).to_vec());
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArrayDims;
    use crate::obs::Recorder;

    fn toy_cfg() -> ArchConfig {
        ArchConfig::with_array(ArrayDims::new(8, 8), 4)
    }

    fn tiny_spec() -> DecoderSpec {
        DecoderSpec {
            name: "Tiny".to_string(),
            layers: 2,
            hidden: 64,
            heads: 4,
            ffn: 128,
            gated_ffn: false,
        }
    }

    fn fast_acfg() -> AutoregConfig {
        AutoregConfig {
            max_batch: 4,
            ctx_bucket: 32,
            sim: SimOptions { memory_model: false, ..SimOptions::default() },
            ..AutoregConfig::default()
        }
    }

    fn burst(n: u64) -> Vec<DecodeRequest> {
        (0..n)
            .map(|id| DecodeRequest {
                id,
                t_arrival: id as f64 * 1e-5,
                prefill_tokens: 16 + (id as usize % 3) * 8,
                // Heterogeneous lengths with a long straggler per
                // max-batch group — the shape static slot-holding is
                // worst at.
                decode_steps: 2 + (id as usize % 4) * 8,
            })
            .collect()
    }

    #[test]
    fn traffic_is_seeded_and_in_range() {
        let spec = DecodeTrafficSpec {
            prefill: (8, 16),
            decode: (2, 5),
            ..DecodeTrafficSpec::poisson(500.0, 0.05, 11)
        };
        let a = generate_decode(&spec);
        let b = generate_decode(&spec);
        assert_eq!(a, b, "same seed ⇒ same trace");
        assert!(!a.is_empty());
        let c = generate_decode(&DecodeTrafficSpec { seed: 12, ..spec });
        assert_ne!(a, c, "different seed ⇒ different trace");
        for (i, r) in a.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.prefill_tokens >= 8 && r.prefill_tokens <= 16);
            assert!(r.decode_steps >= 2 && r.decode_steps <= 5);
            assert!(r.t_arrival >= 0.0 && r.t_arrival < spec.duration_s);
        }
        assert!(a.windows(2).all(|w| w[0].t_arrival <= w[1].t_arrival));
    }

    #[test]
    fn continuous_is_deterministic_cold_and_warm() {
        let reqs = burst(10);
        let mut e1 = AutoregEngine::new(&toy_cfg(), &tiny_spec(), fast_acfg());
        let cold = e1.run(&reqs);
        // Warm: same cache, same trace — must be bit-identical and
        // fully memoized (no new simulator invocations).
        let warm = e1.run(&reqs);
        assert_eq!(cold.completed, warm.completed);
        assert_eq!(cold.makespan_s, warm.makespan_s);
        assert_eq!(warm.sim_calls, 0, "second run must hit the cache everywhere");
        assert!(cold.sim_calls > 0);
        // Cache hand-off preserves results exactly.
        let mut e2 = AutoregEngine::from_cache(e1.into_cache(), fast_acfg());
        assert_eq!(e2.run(&reqs), warm);
    }

    #[test]
    fn continuous_conserves_requests_and_tokens() {
        let reqs = burst(12);
        let mut e = AutoregEngine::new(&toy_cfg(), &tiny_spec(), fast_acfg());
        let rep = e.run(&reqs);
        assert_eq!(rep.completed.len() as u64 + rep.rejected, reqs.len() as u64);
        assert_eq!(rep.rejected, 0);
        let want: u64 = reqs.iter().map(|r| r.decode_steps as u64).sum();
        assert_eq!(rep.generated_tokens, want, "every requested token generated exactly once");
        for s in &rep.completed {
            assert!(s.t_first_token >= s.t_arrival);
            assert!(s.t_end >= s.t_first_token);
            assert!(s.ttft_s() >= 0.0 && s.tpot_s() >= 0.0);
        }
        assert!(rep.busy_s <= rep.makespan_s + 1e-12);
        assert!(rep.peak_batch >= 1 && rep.peak_batch <= fast_acfg().max_batch);
    }

    #[test]
    fn events_match_report() {
        let reqs = burst(6);
        let mut e = AutoregEngine::new(&toy_cfg(), &tiny_spec(), fast_acfg());
        let mut rec = Recorder::new();
        let rep = e.run_traced(&reqs, &mut rec);
        let events = rec.into_events();
        let joins = events.iter().filter(|ev| matches!(ev, Event::RequestJoin { .. })).count();
        let leaves = events.iter().filter(|ev| matches!(ev, Event::RequestLeave { .. })).count();
        let steps = events.iter().filter(|ev| matches!(ev, Event::DecodeStep { .. })).count();
        assert_eq!(joins as u64, rep.prefills);
        assert_eq!(leaves, rep.completed.len());
        assert_eq!(steps as u64, rep.iterations);
    }

    #[test]
    fn kv_admission_bounds_the_batch() {
        // Shrink the SRAM so KV capacity (not max_batch) is the
        // binding constraint: two final footprints fill it exactly.
        let cfg = ArchConfig { bank_kb: 1, ..toy_cfg() };
        let spec = tiny_spec();
        let kv = KvModel::for_decoder(&cfg, &spec);
        let cap = kv.capacity_tokens(&cfg) as usize;
        assert!(cap >= 8, "1 KiB banks must still hold a few tokens: {cap}");
        let reqs: Vec<DecodeRequest> = (0..4)
            .map(|id| DecodeRequest {
                id,
                t_arrival: 0.0,
                prefill_tokens: cap / 2 - 2,
                decode_steps: 2,
            })
            .collect();
        let mut e = AutoregEngine::new(&cfg, &spec, fast_acfg());
        let rep = e.run(&reqs);
        assert_eq!(rep.completed.len(), 4, "all served, just not together");
        assert_eq!(rep.rejected, 0);
        assert!(rep.peak_batch <= 2, "KV capacity admits at most 2 at once: {}", rep.peak_batch);
        assert!(rep.peak_kv_bytes <= cfg.sram_bytes() as u64);
    }

    #[test]
    fn oversized_request_is_rejected_not_wedged() {
        let cfg = ArchConfig { bank_kb: 1, ..toy_cfg() };
        let spec = tiny_spec();
        let kv = KvModel::for_decoder(&cfg, &spec);
        let cap = kv.capacity_tokens(&cfg) as usize;
        let mut reqs: Vec<DecodeRequest> = (0..3)
            .map(|id| DecodeRequest {
                id,
                t_arrival: id as f64 * 1e-5,
                prefill_tokens: 4,
                decode_steps: 2,
            })
            .collect();
        reqs.push(DecodeRequest {
            id: 99,
            t_arrival: 0.0,
            prefill_tokens: cap + 1,
            decode_steps: 2,
        });
        for policy in [AutoregPolicy::Continuous, AutoregPolicy::Static] {
            let mut e = AutoregEngine::new(
                &cfg,
                &spec,
                AutoregConfig { policy, ..fast_acfg() },
            );
            let rep = e.run(&reqs);
            assert_eq!(rep.rejected, 1, "{policy:?}");
            assert_eq!(rep.completed.len(), 3, "{policy:?}");
        }
    }

    #[test]
    fn optimistic_admission_evicts_and_still_finishes() {
        let cfg = ArchConfig { bank_kb: 1, ..toy_cfg() };
        let spec = tiny_spec();
        let kv = KvModel::for_decoder(&cfg, &spec);
        let cap = kv.capacity_tokens(&cfg) as usize;
        assert!(cap >= 12, "test needs room for three joiners: {cap}");
        // Three requests fit at admission but together outgrow the
        // capacity (one token each per iteration), forcing evictions;
        // each alone stays servable (p + steps == cap).
        let p = cap / 3 - 2;
        let steps = cap - p;
        let reqs: Vec<DecodeRequest> = (0..3)
            .map(|id| DecodeRequest { id, t_arrival: 0.0, prefill_tokens: p, decode_steps: steps })
            .collect();
        let acfg = AutoregConfig { optimistic: true, ..fast_acfg() };
        let mut e = AutoregEngine::new(&cfg, &spec, acfg.clone());
        let mut rec = Recorder::new();
        let rep = e.run_traced(&reqs, &mut rec);
        assert!(rep.evictions > 0, "growth past capacity must evict");
        assert_eq!(rep.completed.len(), 3, "evicted requests re-prefill and finish");
        assert_eq!(rep.rejected, 0);
        let evs = rec.into_events();
        let evict_events = evs.iter().filter(|ev| matches!(ev, Event::KvEvict { .. })).count();
        assert_eq!(evict_events as u64, rep.evictions);
        assert!(rep.prefills > 3, "re-prefills counted");
        // Determinism under eviction too.
        let mut e2 = AutoregEngine::new(&cfg, &spec, acfg);
        assert_eq!(e2.run(&reqs), rep);
    }

    #[test]
    fn static_holds_slots_until_longest_member_finishes() {
        let cfg = toy_cfg();
        let spec = tiny_spec();
        let reqs = vec![
            DecodeRequest { id: 0, t_arrival: 0.0, prefill_tokens: 16, decode_steps: 3 },
            DecodeRequest { id: 1, t_arrival: 0.0, prefill_tokens: 16, decode_steps: 1 },
        ];
        let mut e = AutoregEngine::new(
            &cfg,
            &spec,
            AutoregConfig { policy: AutoregPolicy::Static, max_batch: 2, ..fast_acfg() },
        );
        let rep = e.run(&reqs);
        assert_eq!(rep.completed.len(), 2);
        // Prefill phase + 2 decode iterations (tokens 2 and 3 of id 0).
        assert_eq!(rep.iterations, 3);
        let short = rep.completed.iter().find(|s| s.id == 1).expect("served");
        let long = rep.completed.iter().find(|s| s.id == 0).expect("served");
        assert_eq!(short.t_first_token, long.t_first_token, "batch prefills together");
        assert_eq!(short.t_end, short.t_first_token, "single-token request ends at prefill");
        assert!(long.t_end > long.t_first_token);
        assert_eq!(rep.makespan_s, long.t_end);
    }

    #[test]
    fn continuous_beats_static_on_a_loaded_trace() {
        let cfg = toy_cfg();
        let spec = tiny_spec();
        let acfg = fast_acfg();
        // Saturating burst: arrivals outpace service, so static pays
        // batch-formation waits and slot-holding that continuous
        // avoids — it must finish the same work sooner and deliver
        // first tokens faster.
        let reqs = burst(16);
        let mut cont = AutoregEngine::new(&cfg, &spec, acfg.clone());
        let rc = cont.run(&reqs);
        let mut stat = AutoregEngine::from_cache(
            cont.into_cache(),
            AutoregConfig { policy: AutoregPolicy::Static, ..acfg },
        );
        let rs = stat.run(&reqs);
        assert_eq!(rc.completed.len(), rs.completed.len());
        assert!(
            rc.makespan_s < rs.makespan_s,
            "continuous {} vs static {}",
            rc.makespan_s,
            rs.makespan_s
        );
        let mean_ttft = |r: &AutoregReport| {
            let s: f64 = r.completed.iter().map(ServedDecode::ttft_s).sum();
            s / r.completed.len() as f64
        };
        assert!(mean_ttft(&rc) < mean_ttft(&rs), "iteration-level joins cut TTFT");
    }

    #[test]
    fn decode_sweep_is_thread_invariant() {
        let cfg = toy_cfg();
        let spec = tiny_spec();
        let acfg = fast_acfg();
        let sweep = |threads| {
            decode_sweep(
                &cfg,
                &spec,
                &acfg,
                &DecodeSweepOptions {
                    qps: vec![200.0, 800.0],
                    duration_s: 0.02,
                    seed: 7,
                    prefill: (8, 24),
                    decode: (2, 6),
                    ttft_deadline_s: 0.05,
                    tpot_deadline_s: 0.01,
                    threads: Some(threads),
                },
            )
        };
        let one = sweep(1);
        let four = sweep(4);
        assert_eq!(one, four, "SOSA_THREADS must not change results");
        assert_eq!(one.len(), 4, "2 rates × 2 policies");
        assert_eq!(one[0].policy, "continuous");
        assert_eq!(one[1].policy, "static");
    }

    #[test]
    fn sweep_csv_and_table_align() {
        let p = DecodeSweepPoint {
            qps: 100.0,
            policy: "continuous",
            ttft_p50_s: 1e-3,
            ttft_p99_s: 2e-3,
            tpot_p50_s: 1e-4,
            tpot_p99_s: 2e-4,
            goodput_qps: 90.0,
            completed: 9,
            evictions: 0,
            busy_frac: 0.5,
        };
        let dir = std::env::temp_dir().join("sosa_autoreg_csv_test");
        std::fs::create_dir_all(&dir).expect("tmp dir");
        let path = dir.join("decode_sweep.csv");
        write_decode_sweep_csv(&path, &[p]).expect("write");
        let text = std::fs::read_to_string(&path).expect("read");
        assert!(text.starts_with("qps,policy,ttft_p50_ms,"), "{text}");
        assert!(text.contains("100.0,continuous,1.000,2.000,0.100,0.200,90.0,9,0,50.0"), "{text}");
        let rendered = decode_sweep_table(&[p]).render();
        assert!(rendered.contains("continuous"), "{rendered}");
    }

    #[test]
    fn capacity_estimate_is_positive_and_batch_scaled() {
        let mut e = AutoregEngine::new(&toy_cfg(), &tiny_spec(), fast_acfg());
        let cap = e.capacity_qps(16, 4);
        assert!(cap > 0.0);
        let mut e1 = AutoregEngine::new(
            &toy_cfg(),
            &tiny_spec(),
            AutoregConfig { max_batch: 1, ..fast_acfg() },
        );
        assert!(cap > e1.capacity_qps(16, 4), "batching amortizes per-request cost");
    }
}
