//! Deterministic discrete-event serving engine.
//!
//! One [`Engine`] models one accelerator (or one pod partition) with a
//! single execution context: the static scheduler runs one batch (or
//! one co-scheduled batch group, §6.1) at a time.  Requests queue per
//! tenant; a dynamic batcher launches when a batch fills
//! (`max_batch`), when the head request has waited `max_wait_s`, or
//! when the trace is drained.  Batch execution time comes from the
//! cycle-level cost model — each batch composition is **compiled once**
//! into a reusable [`crate::compile::CompiledProgram`] and executed
//! through the memoized [`CostCache`], so million-request traces cost
//! only a handful of compile + execute invocations.
//!
//! The loop is strictly deterministic: time advances monotonically,
//! ties break on tenant index, and no wall-clock or hash-iteration
//! order leaks into results — equal inputs produce byte-identical
//! reports.

// lint:allow(cast, file) — the casts here pack tenant indices and
// pod-unit counts into trace events; both are bounded by the request
// list length and `num_pods` (verified ≤ u32 at fleet construction).
use std::collections::{HashMap, VecDeque};

use crate::arch::ArchConfig;
use crate::compile::CompiledProgram;
use crate::obs::{Event, LaunchReason, NullSink, TraceSink};
use crate::sim::{SimContext, SimOptions};
use crate::stats::RunStats;
use crate::workloads::ModelGraph;

use super::traffic::{Arrival, Tenant};

/// Dynamic batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum batch units per tenant per launch (a request
    /// contributes its `batch` field; online requests are 1 unit
    /// each).  With `coschedule > 1` a launch carries up to
    /// `coschedule × max_batch` units across its tenant group.
    pub max_batch: usize,
    /// Maximum seconds the head-of-line request may wait for the batch
    /// to fill before launching anyway.
    pub max_wait_s: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait_s: 2e-3 }
    }
}

/// Admission control at enqueue time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Queue without bound.
    Unbounded,
    /// Reject arrivals once the tenant's queue holds this many
    /// requests (shed load instead of growing latency without bound).
    MaxQueue(usize),
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub policy: BatchPolicy,
    pub admission: Admission,
    /// Distinct tenants co-scheduled per launch (1 = one model at a
    /// time; 2 reproduces the paper's §6.1 tenant pairs).
    pub coschedule: usize,
    /// Cost-model options.
    pub sim: SimOptions,
    /// Keep per-launch [`RunStats`] in the report (off by default:
    /// large traces would hold one entry per batch).
    pub record_group_stats: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: BatchPolicy::default(),
            admission: Admission::Unbounded,
            coschedule: 1,
            sim: SimOptions::default(),
            record_group_stats: false,
        }
    }
}

/// Completion record for one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServedRequest {
    pub id: u64,
    /// Tenant index (engine-local; partition drivers remap to global).
    pub tenant: usize,
    /// Batch units this request carried.
    pub batch: usize,
    pub t_arrival: f64,
    /// When its batch group started executing.
    pub t_start: f64,
    /// When its batch group completed.
    pub t_end: f64,
}

impl ServedRequest {
    /// End-to-end latency (queueing + service).
    pub fn latency_s(&self) -> f64 {
        self.t_end - self.t_arrival
    }

    /// Time spent queued before the batch launched.
    pub fn queue_s(&self) -> f64 {
        self.t_start - self.t_arrival
    }

    /// Service (batch execution) time.
    pub fn service_s(&self) -> f64 {
        self.t_end - self.t_start
    }
}

/// Outcome of one engine run.
#[derive(Clone, Debug, Default)]
pub struct EngineReport {
    /// Completion records in launch order.
    pub completed: Vec<ServedRequest>,
    /// Requests shed by admission control.
    pub rejected: u64,
    /// Rejections per tenant index.
    pub rejected_by_tenant: Vec<u64>,
    /// Time of the last completion (0 when nothing ran).
    pub makespan_s: f64,
    /// Seconds the accelerator spent executing batches.
    pub busy_s: f64,
    /// Batch groups launched.
    pub batches: u64,
    /// Ops completed (2 × MACs).
    pub total_ops: u64,
    /// Simulator invocations during this run (memoization diagnostic;
    /// 0 when a warm cache served every batch).
    pub sim_calls: u64,
    /// Per-launch stats when `record_group_stats` is set.
    pub group_stats: Vec<RunStats>,
}

impl EngineReport {
    /// Completed requests per second of makespan.
    pub fn throughput_qps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.completed.len() as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Achieved ops/s over the makespan.
    pub fn achieved_ops(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.total_ops as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Accelerator busy fraction over the makespan.
    pub fn busy_frac(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.busy_s / self.makespan_s
        } else {
            0.0
        }
    }
}

/// Memoized batch cost entry.
#[derive(Clone, Debug)]
pub struct CostEntry {
    /// Batch-group execution seconds on the engine's configuration.
    pub seconds: f64,
    /// Ops the group completes.
    pub ops: u64,
    /// Full simulator stats for the group.
    pub stats: RunStats,
}

/// Two-level memoization over batch-group compositions — the key is
/// the exact ordered `(tenant, batch)` list:
///
/// 1. **Compiled programs**: each distinct composition is compiled
///    once ([`crate::compile::compile_multi_with`] — merged tiling +
///    per-layer strategy resolution) and the [`CompiledProgram`] is
///    cached, keyed by the composition (the model set, batch sizes and
///    tiling spec are fixed per cache).
/// 2. **Batch costs**: the executed [`RunStats`]/seconds per
///    composition, so repeated groups cost a `HashMap` hit.
///
/// Cache misses run on a pooled [`SimContext`] (unless `opts.pooling`
/// is off, the cold A/B baseline — scheduler state rebuilt, the
/// program recompiled per miss and not retained, mimicking the old
/// fused path), so even the misses skip the scheduler's per-run
/// allocation.
///
/// Retention: both maps live for the cache's lifetime.  Their
/// cardinality is the number of *distinct* compositions the batcher
/// produces — bounded by the batch-size × tenant-group combinations,
/// not the trace length (the premise that makes memoization pay) —
/// but a compiled program is orders of magnitude larger than a cost
/// entry, so callers juggling many caches (per-worker, per-partition)
/// should drop caches they are done with rather than hoard them.
#[derive(Debug)]
pub struct CostCache {
    cfg: ArchConfig,
    opts: SimOptions,
    models: Vec<ModelGraph>,
    map: HashMap<Vec<(usize, usize)>, CostEntry>,
    programs: HashMap<Vec<(usize, usize)>, CompiledProgram>,
    ctx: SimContext,
    /// Simulator (execute-phase) invocations so far.
    pub sim_calls: u64,
    /// Compile-phase invocations so far.  Each distinct composition
    /// compiles at most once on the pooled path (also via
    /// [`CostCache::program`], which compiles without executing); with
    /// `pooling` off it recompiles per cost miss.
    pub compile_calls: u64,
}

impl CostCache {
    /// New cache over a configuration and the tenant models.
    pub fn new(cfg: ArchConfig, models: Vec<ModelGraph>, opts: SimOptions) -> Self {
        CostCache {
            cfg,
            opts,
            models,
            map: HashMap::new(),
            programs: HashMap::new(),
            ctx: SimContext::new(),
            sim_calls: 0,
            compile_calls: 0,
        }
    }

    /// Number of tenant models the cache covers.
    pub fn num_tenants(&self) -> usize {
        self.models.len()
    }

    /// Compiled programs currently cached.
    pub fn programs_cached(&self) -> usize {
        self.programs.len()
    }

    /// Compile (or fetch) the program for a composition without
    /// executing it.
    pub fn program(&mut self, comp: &[(usize, usize)]) -> &CompiledProgram {
        self.ensure_program(comp);
        self.programs.get(comp).expect("ensured above")
    }

    fn ensure_program(&mut self, comp: &[(usize, usize)]) {
        if self.programs.contains_key(comp) {
            return;
        }
        let batched: Vec<ModelGraph> = comp
            .iter()
            .map(|&(k, b)| self.models[k].with_batch(b.max(1)))
            .collect();
        let refs: Vec<&ModelGraph> = batched.iter().collect();
        let cp = crate::compile::compile_multi_with(&mut self.ctx, &self.cfg, &refs, &self.opts);
        self.compile_calls += 1;
        self.programs.insert(comp.to_vec(), cp);
    }

    /// Cost of a batch group given as `(tenant index, batch units)`
    /// entries (order is the co-schedule order).
    pub fn cost(&mut self, comp: &[(usize, usize)]) -> CostEntry {
        if let Some(e) = self.map.get(comp) {
            return e.clone();
        }
        if !self.opts.pooling {
            // Cold A/B baseline: rebuild the scheduler state and
            // recompile per call (the fused pre-pipeline path).
            self.ctx = SimContext::new();
            self.programs.remove(comp);
        }
        self.ensure_program(comp);
        let cp = self.programs.get(comp).expect("ensured above");
        let stats = cp.execute_with(&mut self.ctx, &self.cfg, &self.opts);
        let entry = CostEntry {
            seconds: stats.exec_seconds(&self.cfg),
            ops: cp.models.iter().map(ModelGraph::total_ops).sum(),
            stats,
        };
        self.sim_calls += 1;
        self.map.insert(comp.to_vec(), entry.clone());
        if !self.opts.pooling {
            // The fused baseline held no artifact; don't retain one.
            self.programs.remove(comp);
        }
        entry
    }
}

/// The serving engine for one accelerator (or pod partition).
pub struct Engine {
    ecfg: EngineConfig,
    n_tenants: usize,
    cache: CostCache,
}

impl Engine {
    /// New engine over a configuration and tenant set.
    pub fn new(cfg: ArchConfig, tenants: &[Tenant], ecfg: EngineConfig) -> Self {
        assert!(!tenants.is_empty(), "engine needs at least one tenant");
        let models: Vec<ModelGraph> = tenants.iter().map(|t| t.model.clone()).collect();
        let cache = CostCache::new(cfg, models, ecfg.sim.clone());
        Engine { ecfg, n_tenants: tenants.len(), cache }
    }

    /// New engine over an existing (possibly warm) [`CostCache`] —
    /// batch costs memoized by a previous engine on the same
    /// configuration carry over.  Used by load sweeps to avoid
    /// re-simulating identical batch compositions at every offered
    /// rate.  Panics if the cache was built for a different
    /// configuration, cost-model options, or tenant model set: its
    /// memoized entries would silently be wrong for this engine.
    pub fn with_cache(
        cfg: &ArchConfig,
        tenants: &[Tenant],
        cache: CostCache,
        ecfg: EngineConfig,
    ) -> Self {
        assert!(!tenants.is_empty(), "engine needs at least one tenant");
        assert!(
            cache.cfg == *cfg,
            "cost cache was built for a different ArchConfig"
        );
        assert!(
            cache.opts == ecfg.sim,
            "cost cache was built with different SimOptions"
        );
        assert!(
            cache.num_tenants() == tenants.len()
                && cache.models.iter().zip(tenants).all(|(m, t)| *m == t.model),
            "cost cache was built over a different tenant model set"
        );
        Engine { ecfg, n_tenants: cache.num_tenants(), cache }
    }

    /// Recover the cache (and its memoized costs) after a run.
    pub fn into_cache(self) -> CostCache {
        self.cache
    }

    /// Pop up to `max_batch` batch units from a queue (always at least
    /// the head request, even if it alone exceeds the cap).
    fn pop_batch(q: &mut VecDeque<Arrival>, max_batch: usize) -> (usize, Vec<Arrival>) {
        let mut total = 0usize;
        let mut popped = Vec::new();
        while let Some(front) = q.front() {
            let b = front.batch.max(1);
            if !popped.is_empty() && total + b > max_batch {
                break;
            }
            total += b;
            popped.push(q.pop_front().expect("front checked"));
            if total >= max_batch {
                break;
            }
        }
        (total, popped)
    }

    /// Run the trace to completion (arrivals must be time-sorted).
    pub fn run(&mut self, arrivals: &[Arrival]) -> EngineReport {
        self.run_traced(arrivals, &mut NullSink)
    }

    /// [`Engine::run`] with a flight-recorder sink: emits
    /// [`Event::RequestArrive`]/[`Event::RequestReject`] at admission,
    /// [`Event::BatchLaunch`] (with the batch-formation reason) per
    /// launch, and [`Event::RequestServed`] per completion, carrying
    /// `t_mfree` — when the accelerator came free for the request's
    /// batch — so exporters can split latency into
    /// queue-wait/batch-wait/service.  Identical report to `run` for
    /// any sink; the engine's own [`CostCache`] context never gets a
    /// sink (its memoized cost lookups would make scheduler-level
    /// events depend on cache warmness).
    pub fn run_traced(&mut self, arrivals: &[Arrival], sink: &mut dyn TraceSink) -> EngineReport {
        debug_assert!(arrivals.windows(2).all(|w| w[0].t <= w[1].t));
        let nt = self.n_tenants;
        let max_batch = self.ecfg.policy.max_batch.max(1);
        let max_wait = self.ecfg.policy.max_wait_s.max(0.0);
        let coschedule = self.ecfg.coschedule.max(1);

        let mut queues: Vec<VecDeque<Arrival>> = (0..nt).map(|_| VecDeque::new()).collect();
        let mut report = EngineReport { rejected_by_tenant: vec![0; nt], ..Default::default() };
        // Warm caches carry sim_calls across runs; report the delta so
        // the field stays a per-run diagnostic.
        let sim_calls_at_entry = self.cache.sim_calls;
        let mut i = 0usize; // next arrival to absorb
        let mut t = 0.0f64; // simulation clock
        let mut t_free = 0.0f64; // accelerator free time

        loop {
            // Absorb every arrival at or before the clock.
            while i < arrivals.len() && arrivals[i].t <= t {
                let a = arrivals[i];
                i += 1;
                assert!(a.tenant < nt, "arrival tenant out of range");
                let reject = match self.ecfg.admission {
                    Admission::Unbounded => false,
                    Admission::MaxQueue(cap) => queues[a.tenant].len() >= cap,
                };
                if reject {
                    report.rejected += 1;
                    report.rejected_by_tenant[a.tenant] += 1;
                    if sink.enabled() {
                        sink.event(Event::RequestReject {
                            id: a.id,
                            tenant: a.tenant as u32,
                            t: a.t,
                        });
                    }
                } else {
                    queues[a.tenant].push_back(a);
                    if sink.enabled() {
                        sink.event(Event::RequestArrive {
                            id: a.id,
                            tenant: a.tenant as u32,
                            t: a.t,
                        });
                    }
                }
            }

            let any_queued = queues.iter().any(|q| !q.is_empty());
            if !any_queued {
                if i >= arrivals.len() {
                    break; // drained and idle: done
                }
                t = arrivals[i].t.max(t);
                continue;
            }
            if t < t_free {
                t = t_free; // wait for the in-flight batch
                continue;
            }

            // Accelerator is idle and work is queued.  Primary tenant:
            // oldest head-of-line request, ties to the lowest index.
            let primary = (0..nt)
                .filter(|&k| !queues[k].is_empty())
                .min_by(|&a, &b| queues[a][0].t.total_cmp(&queues[b][0].t).then(a.cmp(&b)))
                .expect("some queue is non-empty");
            let head_t = queues[primary][0].t;
            let mut ready = 0usize;
            for r in queues[primary].iter() {
                ready += r.batch.max(1);
                if ready >= max_batch {
                    break;
                }
            }
            let drained = i >= arrivals.len();

            if ready >= max_batch || drained || t >= head_t + max_wait {
                // Launch: primary batch plus up to `coschedule - 1`
                // co-scheduled tenants, oldest head first.
                let mut others: Vec<usize> = (0..nt)
                    .filter(|&k| k != primary && !queues[k].is_empty())
                    .collect();
                others.sort_by(|&a, &b| {
                    queues[a][0].t.total_cmp(&queues[b][0].t).then(a.cmp(&b))
                });
                let mut chosen = vec![primary];
                chosen.extend(others.into_iter().take(coschedule - 1));

                let mut comp: Vec<(usize, usize)> = Vec::with_capacity(chosen.len());
                let mut popped_all: Vec<Arrival> = Vec::new();
                for &k in &chosen {
                    let (units, popped) = Self::pop_batch(&mut queues[k], max_batch);
                    comp.push((k, units));
                    popped_all.extend(popped);
                }
                let entry = self.cache.cost(&comp);
                let start = t;
                let end = start + entry.seconds;
                if sink.enabled() {
                    // Reason follows the launch condition's evaluation
                    // order; `t_free` still holds the pre-launch value.
                    let reason = if ready >= max_batch {
                        LaunchReason::Filled
                    } else if drained {
                        LaunchReason::Drained
                    } else {
                        LaunchReason::Timeout
                    };
                    let units = comp.iter().map(|&(_, u)| u as u32).sum();
                    sink.event(Event::BatchLaunch { t_start: start, t_end: end, units, reason });
                    for a in &popped_all {
                        sink.event(Event::RequestServed {
                            id: a.id,
                            tenant: a.tenant as u32,
                            t_arrival: a.t,
                            t_mfree: t_free,
                            t_start: start,
                            t_end: end,
                        });
                    }
                }
                for a in &popped_all {
                    report.completed.push(ServedRequest {
                        id: a.id,
                        tenant: a.tenant,
                        batch: a.batch.max(1),
                        t_arrival: a.t,
                        t_start: start,
                        t_end: end,
                    });
                }
                report.batches += 1;
                report.busy_s += entry.seconds;
                report.total_ops += entry.ops;
                if self.ecfg.record_group_stats {
                    report.group_stats.push(entry.stats.clone());
                }
                t_free = end;
                t = end;
            } else {
                // Wait for the batch to fill or the head to time out.
                let deadline = head_t + max_wait;
                t = if i < arrivals.len() { arrivals[i].t.min(deadline) } else { deadline };
            }
        }

        report.makespan_s = t_free;
        report.sim_calls = self.cache.sim_calls - sim_calls_at_entry;
        report
    }
}

/// Serve a trace on the whole accelerator (no partitioning): every
/// tenant shares one engine, one model group at a time unless
/// `ecfg.coschedule > 1`.
pub fn serve_shared(
    cfg: &ArchConfig,
    tenants: &[Tenant],
    arrivals: &[Arrival],
    ecfg: &EngineConfig,
) -> EngineReport {
    Engine::new(cfg.clone(), tenants, ecfg.clone()).run(arrivals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, ArrayDims};
    use crate::serve::traffic::{generate, ArrivalProcess, TrafficSpec};

    fn toy_cfg() -> ArchConfig {
        ArchConfig::with_array(ArrayDims::new(8, 8), 4)
    }

    fn toy_tenant(name: &str) -> Tenant {
        let mut g = ModelGraph::new(name);
        g.add("fc1", 64, 64, 64, vec![]);
        g.add("fc2", 64, 64, 32, vec![0]);
        Tenant::new(g, 1.0)
    }

    fn fast_sim() -> SimOptions {
        SimOptions { memory_model: false, ..Default::default() }
    }

    fn at(times: &[f64]) -> Vec<Arrival> {
        times
            .iter()
            .enumerate()
            .map(|(i, &t)| Arrival { t, tenant: 0, id: i as u64, batch: 1 })
            .collect()
    }

    fn ecfg(max_batch: usize, max_wait_s: f64) -> EngineConfig {
        EngineConfig {
            policy: BatchPolicy { max_batch, max_wait_s },
            sim: fast_sim(),
            ..Default::default()
        }
    }

    #[test]
    fn batches_fill_to_max_batch() {
        let tenants = vec![toy_tenant("a")];
        let mut e = Engine::new(toy_cfg(), &tenants, ecfg(4, 1.0));
        let rep = e.run(&at(&[0.0; 8]));
        assert_eq!(rep.completed.len(), 8);
        assert_eq!(rep.batches, 2, "8 simultaneous arrivals at max_batch 4");
        // One distinct composition (batch of 4) → one simulator call.
        assert_eq!(rep.sim_calls, 1);
        // First four share a group; the rest start where it ended.
        assert_eq!(rep.completed[0].t_end, rep.completed[3].t_end);
        assert_eq!(rep.completed[4].t_start, rep.completed[0].t_end);
    }

    #[test]
    fn max_wait_launches_partial_batch() {
        let tenants = vec![toy_tenant("a")];
        // Second arrival outside the wait window: two singleton batches.
        let mut e = Engine::new(toy_cfg(), &tenants, ecfg(100, 0.01));
        let rep = e.run(&at(&[0.0, 0.1]));
        assert_eq!(rep.batches, 2);
        let first = rep.completed.iter().find(|r| r.id == 0).unwrap();
        assert!((first.t_start - 0.01).abs() < 1e-12, "held for max_wait");
        // Second arrival inside the window: one batch of two.
        let mut e = Engine::new(toy_cfg(), &tenants, ecfg(100, 0.01));
        let rep = e.run(&at(&[0.0, 0.001]));
        assert_eq!(rep.batches, 1);
        assert_eq!(rep.completed.len(), 2);
    }

    #[test]
    fn drained_trace_launches_immediately() {
        let tenants = vec![toy_tenant("a")];
        // One arrival, huge wait: no future arrivals, so no reason to hold.
        let mut e = Engine::new(toy_cfg(), &tenants, ecfg(100, 10.0));
        let rep = e.run(&at(&[0.0]));
        assert_eq!(rep.batches, 1);
        assert_eq!(rep.completed[0].t_start, 0.0);
    }

    #[test]
    fn admission_control_sheds_load() {
        let tenants = vec![toy_tenant("a")];
        let mut cfg = ecfg(1, 0.0);
        cfg.admission = Admission::MaxQueue(1);
        let mut e = Engine::new(toy_cfg(), &tenants, cfg);
        let rep = e.run(&at(&[0.0, 0.0, 0.0]));
        assert_eq!(rep.completed.len() as u64 + rep.rejected, 3);
        assert_eq!(rep.rejected, 2, "cap 1: head admitted, rest shed");
        assert_eq!(rep.rejected_by_tenant[0], 2);
    }

    #[test]
    fn latency_decomposes_into_queue_plus_service() {
        let tenants = vec![toy_tenant("a")];
        let mut e = Engine::new(toy_cfg(), &tenants, ecfg(1, 0.0));
        let rep = e.run(&at(&[0.0, 0.0]));
        for r in &rep.completed {
            assert!((r.latency_s() - (r.queue_s() + r.service_s())).abs() < 1e-15);
            assert!(r.service_s() > 0.0);
        }
        // Second request queues behind the first batch.
        let second = rep.completed.iter().find(|r| r.id == 1).unwrap();
        assert!(second.queue_s() > 0.0);
    }

    #[test]
    fn coschedule_groups_tenants_per_launch() {
        let tenants = vec![toy_tenant("a"), toy_tenant("b")];
        let arrivals = vec![
            Arrival { t: 0.0, tenant: 0, id: 0, batch: 1 },
            Arrival { t: 0.0, tenant: 1, id: 1, batch: 1 },
        ];
        let mut cfg = ecfg(1, 0.0);
        cfg.coschedule = 2;
        let mut e = Engine::new(toy_cfg(), &tenants, cfg);
        let rep = e.run(&arrivals);
        assert_eq!(rep.batches, 1, "both tenants co-scheduled in one group");
        assert_eq!(rep.completed[0].t_end, rep.completed[1].t_end);
    }

    #[test]
    fn memoization_bounds_simulator_calls() {
        let tenants = vec![toy_tenant("a")];
        let spec = TrafficSpec::poisson(2000.0, 1.0, 5);
        let arrivals = generate(&spec, &tenants);
        assert!(arrivals.len() > 500);
        let mut e = Engine::new(toy_cfg(), &tenants, ecfg(4, 1e-3));
        let rep = e.run(&arrivals);
        assert_eq!(rep.completed.len(), arrivals.len());
        // Batch sizes range over 1..=4 → at most 4 distinct sims.
        assert!(rep.sim_calls <= 4, "sim_calls {}", rep.sim_calls);
        assert!(rep.batches < arrivals.len() as u64, "batching must merge");
    }

    #[test]
    fn cost_cache_compiles_each_composition_once() {
        let tenants = vec![toy_tenant("a")];
        let models: Vec<ModelGraph> = tenants.iter().map(|t| t.model.clone()).collect();
        let mut cache = CostCache::new(toy_cfg(), models, fast_sim());
        let a1 = cache.cost(&[(0, 1)]);
        let a2 = cache.cost(&[(0, 1)]);
        let b = cache.cost(&[(0, 4)]);
        assert_eq!(a1.seconds, a2.seconds);
        assert!(b.seconds > a1.seconds, "bigger batch runs longer");
        assert_eq!(cache.sim_calls, 2, "two distinct compositions executed");
        assert_eq!(cache.compile_calls, 2, "each compiled exactly once");
        assert_eq!(cache.programs_cached(), 2);
        // The compiled artifact is directly addressable too.
        assert_eq!(cache.program(&[(0, 4)]).models[0].ops[0].m, 4 * 64);
        assert_eq!(cache.compile_calls, 2, "program() reuses the cache");
    }

    #[test]
    fn cold_cost_cache_matches_pooled() {
        // pooling = false (rebuild + recompile per miss) must be a pure
        // A/B toggle: identical entries.
        let tenants = vec![toy_tenant("a")];
        let models: Vec<ModelGraph> = tenants.iter().map(|t| t.model.clone()).collect();
        let mut warm = CostCache::new(toy_cfg(), models.clone(), fast_sim());
        let cold_opts = SimOptions { pooling: false, ..fast_sim() };
        let mut cold = CostCache::new(toy_cfg(), models, cold_opts);
        for comp in [vec![(0usize, 1usize)], vec![(0, 3)], vec![(0, 1)]] {
            let w = warm.cost(&comp);
            let c = cold.cost(&comp);
            assert_eq!(w.seconds, c.seconds);
            assert_eq!(w.stats, c.stats);
        }
    }

    #[test]
    fn warm_cache_reuse_is_transparent() {
        let tenants = vec![toy_tenant("a")];
        let arrivals = at(&[0.0; 8]);
        let mut cold_engine = Engine::new(toy_cfg(), &tenants, ecfg(4, 1.0));
        let cold = cold_engine.run(&arrivals);
        let mut e1 = Engine::new(toy_cfg(), &tenants, ecfg(4, 1.0));
        let r1 = e1.run(&arrivals);
        // Hand the warm cache to a fresh engine: identical results,
        // zero additional simulator calls.
        let mut e2 = Engine::with_cache(&toy_cfg(), &tenants, e1.into_cache(), ecfg(4, 1.0));
        let r2 = e2.run(&arrivals);
        assert_eq!(cold.completed, r2.completed);
        assert_eq!(cold.makespan_s, r2.makespan_s);
        assert_eq!(r1.sim_calls, cold.sim_calls);
        assert_eq!(r2.sim_calls, 0, "warm cache adds no sims");
    }

    #[test]
    fn deterministic_across_runs() {
        let tenants = vec![toy_tenant("a"), toy_tenant("b")];
        let spec = TrafficSpec {
            process: ArrivalProcess::Poisson { qps: 800.0 },
            duration_s: 0.5,
            seed: 9,
        };
        let arrivals = generate(&spec, &tenants);
        let run = || {
            Engine::new(toy_cfg(), &tenants, ecfg(4, 1e-3)).run(&arrivals)
        };
        let a = run();
        let b = run();
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.total_ops, b.total_ops);
    }

    #[test]
    fn empty_trace_yields_empty_report() {
        let tenants = vec![toy_tenant("a")];
        let mut e = Engine::new(toy_cfg(), &tenants, ecfg(4, 1e-3));
        let rep = e.run(&[]);
        assert!(rep.completed.is_empty());
        assert_eq!(rep.makespan_s, 0.0);
        assert_eq!(rep.throughput_qps(), 0.0);
        assert_eq!(rep.achieved_ops(), 0.0);
    }
}
