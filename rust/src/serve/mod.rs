//! Online serving subsystem: a deterministic, trace-driven
//! discrete-event engine layered on the cycle-level cost model.
//!
//! The offline [`crate::coordinator`] answers "how fast does this
//! request list run"; this module answers the production questions the
//! ROADMAP's north star asks — what latency distribution, goodput and
//! sustainable QPS does a SOSA configuration deliver under live
//! traffic?  The pieces:
//!
//! * [`traffic`] — open-loop arrival generation (Poisson, bursty MMPP,
//!   trace replay) over the model zoo with a seeded RNG;
//! * [`engine`] — per-tenant queues, dynamic batching (max-batch +
//!   max-wait), admission control, and a two-level cost cache: each
//!   batch composition is compiled once into a reusable
//!   [`crate::compile::CompiledProgram`] and its executed cost
//!   memoized, so million-request traces need only a handful of
//!   compile + execute invocations;
//! * [`partition`] — static pod partitioning for multi-tenancy: each
//!   tenant gets a power-of-two pod slice simulated as its own
//!   sub-[`crate::ArchConfig`];
//! * [`slo`] — p50/p95/p99 latency, queueing vs service decomposition,
//!   goodput under a deadline, and a load-sweep helper that finds the
//!   saturation knee / max sustainable QPS;
//! * [`autoreg`] — autoregressive serving: prefill–decode request
//!   model, KV-cache capacity admission, and continuous batching
//!   (iteration-level join/leave) vs the static max-batch baseline,
//!   with TTFT/TPOT SLOs ([`slo::analyze_autoreg`]).
//!
//! Everything is deterministic under a fixed seed: equal inputs yield
//! byte-identical reports (no wall clock, no hash-order dependence).
//!
//! ```no_run
//! use sosa::arch::ArchConfig;
//! use sosa::serve::{
//!     analyze, generate, serve_shared, EngineConfig, Tenant, TrafficSpec,
//! };
//! use sosa::workloads::zoo;
//!
//! let cfg = ArchConfig::baseline();
//! let tenants = vec![Tenant::new(zoo::by_name("bert-large").unwrap(), 1.0)];
//! let arrivals = generate(&TrafficSpec::poisson(2000.0, 1.0, 7), &tenants);
//! let rep = serve_shared(&cfg, &tenants, &arrivals, &EngineConfig::default());
//! println!("{}", analyze(&rep, 1.0, 5e-3));
//! ```

pub mod autoreg;
pub mod engine;
pub mod partition;
pub mod slo;
pub mod traffic;

pub use autoreg::{
    decode_sweep, decode_sweep_table, generate_decode, write_decode_sweep_csv, AutoregConfig,
    AutoregEngine, AutoregPolicy, AutoregReport, DecodeCostCache, DecodeRequest,
    DecodeSweepOptions, DecodeSweepPoint, DecodeTrafficSpec, ServedDecode,
};
pub use engine::{
    serve_shared, Admission, BatchPolicy, CostCache, CostEntry, Engine, EngineConfig,
    EngineReport, ServedRequest,
};
pub use partition::{
    partition_pods, partition_pods_under_tdp, serve_partitioned, serve_partitioned_cached,
    serve_partitioned_threads, sub_config, PartitionPlan, TenantPartition,
};
pub use slo::{
    analyze, analyze_autoreg, capacity_qps, default_deadline, load_sweep, max_sustainable_qps,
    percentile, sweep_table, write_sweep_csv, AutoregSlo, LatencyStats, SloReport, SweepOptions,
    SweepPoint, SWEEP_LADDER,
};
pub use traffic::{generate, Arrival, ArrivalProcess, Tenant, TrafficSpec};
