//! SLO accounting over engine reports: latency percentiles, queueing
//! vs service decomposition, goodput under a latency deadline, and a
//! load-sweep helper that locates the saturation knee / maximum
//! sustainable QPS for a configuration.

use crate::arch::ArchConfig;
use crate::error::Result;
use crate::sim::SweepExecutor;
use crate::util::{csv::f, Table};
use crate::workloads::ModelGraph;

use super::engine::{CostCache, Engine, EngineConfig, EngineReport};
use super::partition::serve_partitioned_cached;
use super::traffic::{generate, Tenant, TrafficSpec};

/// Percentile summary of a sample set (seconds).
///
/// An **empty** sample set is represented explicitly: `n = 0` with
/// every statistic `NaN` (rendered as `NaN` in reports and CSVs).  It
/// used to summarize as all-zeros — a window that served nothing
/// reported p99 = 0 ms, indistinguishable from perfect latency.  NaN
/// also fails every `<=` deadline comparison, so empty windows cannot
/// sneak through SLO gates.  Check `n == 0` (or `served()`) before
/// comparing two summaries with `==`: NaN never equals NaN.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyStats {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

pub use crate::stats::percentile;

impl LatencyStats {
    /// Summarize a sample set (sorts a copy; callers keep their order).
    /// Empty input → `n = 0` and NaN statistics (see the type docs).
    pub fn from_samples(samples: &[f64]) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats {
                n: 0,
                mean: f64::NAN,
                p50: f64::NAN,
                p95: f64::NAN,
                p99: f64::NAN,
                max: f64::NAN,
            };
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        LatencyStats {
            n: sorted.len(),
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50: percentile(&sorted, 50.0),
            p95: percentile(&sorted, 95.0),
            p99: percentile(&sorted, 99.0),
            max: *sorted.last().expect("non-empty"),
        }
    }

    /// Whether any sample was observed (`n > 0`).
    pub fn served(&self) -> bool {
        self.n > 0
    }
}

/// Full SLO report for one serving run.
#[derive(Clone, Debug)]
pub struct SloReport {
    /// End-to-end latency (arrival → completion).
    pub latency: LatencyStats,
    /// Queueing component (arrival → batch launch).
    pub queue: LatencyStats,
    /// Service component (batch launch → completion).
    pub service: LatencyStats,
    /// Requests offered (completed + rejected).
    pub offered: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Completions within the deadline.
    pub within_deadline: u64,
    /// Latency deadline used for goodput.
    pub deadline_s: f64,
    /// In-deadline completions per second of horizon.
    pub goodput_qps: f64,
    /// Completions per second of horizon (deadline-blind).
    pub throughput_qps: f64,
    pub makespan_s: f64,
    /// Accelerator busy fraction over the makespan.
    pub busy_frac: f64,
}

/// Compute the SLO report for an engine run.  `horizon_s` is the
/// offered-traffic duration (rates are normalized to it, extended to
/// the makespan if the run overran while draining).
pub fn analyze(rep: &EngineReport, horizon_s: f64, deadline_s: f64) -> SloReport {
    let latencies: Vec<f64> = rep.completed.iter().map(|r| r.latency_s()).collect();
    let queues: Vec<f64> = rep.completed.iter().map(|r| r.queue_s()).collect();
    let services: Vec<f64> = rep.completed.iter().map(|r| r.service_s()).collect();
    let within = latencies.iter().filter(|&&l| l <= deadline_s).count() as u64;
    let span = horizon_s.max(rep.makespan_s);
    let (goodput, throughput) = if span > 0.0 {
        (within as f64 / span, rep.completed.len() as f64 / span)
    } else {
        (0.0, 0.0)
    };
    SloReport {
        latency: LatencyStats::from_samples(&latencies),
        queue: LatencyStats::from_samples(&queues),
        service: LatencyStats::from_samples(&services),
        offered: rep.completed.len() as u64 + rep.rejected,
        completed: rep.completed.len() as u64,
        rejected: rep.rejected,
        within_deadline: within,
        deadline_s,
        goodput_qps: goodput,
        throughput_qps: throughput,
        makespan_s: rep.makespan_s,
        busy_frac: rep.busy_frac(),
    }
}

impl std::fmt::Display for SloReport {
    fn fmt(&self, fm: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            fm,
            "requests : {} offered, {} completed, {} rejected",
            self.offered, self.completed, self.rejected
        )?;
        writeln!(
            fm,
            "latency  : p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  mean {:.3} ms  max {:.3} ms",
            self.latency.p50 * 1e3,
            self.latency.p95 * 1e3,
            self.latency.p99 * 1e3,
            self.latency.mean * 1e3,
            self.latency.max * 1e3
        )?;
        writeln!(
            fm,
            "breakdown: queueing p50 {:.3} / p99 {:.3} ms — service p50 {:.3} / p99 {:.3} ms",
            self.queue.p50 * 1e3,
            self.queue.p99 * 1e3,
            self.service.p50 * 1e3,
            self.service.p99 * 1e3
        )?;
        writeln!(
            fm,
            "goodput  : {:.1} req/s within {:.3} ms deadline ({} of {} in time)",
            self.goodput_qps,
            self.deadline_s * 1e3,
            self.within_deadline,
            self.completed
        )?;
        write!(
            fm,
            "machine  : makespan {:.3} s, busy {:.1} %, throughput {:.1} req/s",
            self.makespan_s,
            100.0 * self.busy_frac,
            self.throughput_qps
        )
    }
}

/// Token-level SLO report for an autoregressive serving run
/// ([`crate::serve::autoreg`]): TTFT (time-to-first-token) and TPOT
/// (time-per-output-token) percentiles with goodput under *separate*
/// deadlines — a completion only counts toward goodput when it met
/// both.
#[derive(Clone, Debug)]
pub struct AutoregSlo {
    /// Arrival → first token.
    pub ttft: LatencyStats,
    /// Mean inter-token gap over each request's decode phase.
    pub tpot: LatencyStats,
    /// Requests offered (completed + rejected).
    pub offered: u64,
    pub completed: u64,
    /// Requests whose KV state alone exceeds the node SRAM.
    pub rejected: u64,
    /// KV evictions across the run (optimistic admission only).
    pub evictions: u64,
    /// Completions within the TTFT deadline.
    pub within_ttft: u64,
    /// Completions within the TPOT deadline.
    pub within_tpot: u64,
    /// Completions within BOTH deadlines (the goodput numerator).
    pub within_both: u64,
    pub ttft_deadline_s: f64,
    pub tpot_deadline_s: f64,
    /// In-deadline (both) completions per second of horizon.
    pub goodput_qps: f64,
    /// Completions per second of horizon (deadline-blind).
    pub throughput_qps: f64,
    /// Generated tokens per second of horizon.
    pub tokens_per_s: f64,
    pub makespan_s: f64,
    /// Accelerator busy fraction over the makespan.
    pub busy_frac: f64,
}

/// Compute the TTFT/TPOT SLO report for an autoregressive run.
/// `horizon_s` is the offered-traffic duration (rates normalize to it,
/// extended to the makespan if the run overran while draining).
pub fn analyze_autoreg(
    rep: &crate::serve::autoreg::AutoregReport,
    horizon_s: f64,
    ttft_deadline_s: f64,
    tpot_deadline_s: f64,
) -> AutoregSlo {
    use crate::serve::autoreg::ServedDecode;
    let ttfts: Vec<f64> = rep.completed.iter().map(ServedDecode::ttft_s).collect();
    let tpots: Vec<f64> = rep.completed.iter().map(ServedDecode::tpot_s).collect();
    let mut within_ttft = 0u64;
    let mut within_tpot = 0u64;
    let mut within_both = 0u64;
    for (&a, &b) in ttfts.iter().zip(&tpots) {
        let ok_a = a <= ttft_deadline_s;
        let ok_b = b <= tpot_deadline_s;
        within_ttft += ok_a as u64;
        within_tpot += ok_b as u64;
        within_both += (ok_a && ok_b) as u64;
    }
    let span = horizon_s.max(rep.makespan_s);
    let (goodput, throughput, tokens) = if span > 0.0 {
        (
            within_both as f64 / span,
            rep.completed.len() as f64 / span,
            rep.generated_tokens as f64 / span,
        )
    } else {
        (0.0, 0.0, 0.0)
    };
    AutoregSlo {
        ttft: LatencyStats::from_samples(&ttfts),
        tpot: LatencyStats::from_samples(&tpots),
        offered: rep.completed.len() as u64 + rep.rejected,
        completed: rep.completed.len() as u64,
        rejected: rep.rejected,
        evictions: rep.evictions,
        within_ttft,
        within_tpot,
        within_both,
        ttft_deadline_s,
        tpot_deadline_s,
        goodput_qps: goodput,
        throughput_qps: throughput,
        tokens_per_s: tokens,
        makespan_s: rep.makespan_s,
        busy_frac: rep.busy_frac(),
    }
}

impl std::fmt::Display for AutoregSlo {
    fn fmt(&self, fm: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            fm,
            "requests : {} offered, {} completed, {} rejected, {} evictions",
            self.offered, self.completed, self.rejected, self.evictions
        )?;
        writeln!(
            fm,
            "ttft     : p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  mean {:.3} ms  max {:.3} ms",
            self.ttft.p50 * 1e3,
            self.ttft.p95 * 1e3,
            self.ttft.p99 * 1e3,
            self.ttft.mean * 1e3,
            self.ttft.max * 1e3
        )?;
        writeln!(
            fm,
            "tpot     : p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  mean {:.3} ms  max {:.3} ms",
            self.tpot.p50 * 1e3,
            self.tpot.p95 * 1e3,
            self.tpot.p99 * 1e3,
            self.tpot.mean * 1e3,
            self.tpot.max * 1e3
        )?;
        writeln!(
            fm,
            "goodput  : {:.1} req/s within ttft {:.3} ms AND tpot {:.3} ms ({} ttft-ok, {} tpot-ok, {} both)",
            self.goodput_qps,
            self.ttft_deadline_s * 1e3,
            self.tpot_deadline_s * 1e3,
            self.within_ttft,
            self.within_tpot,
            self.within_both
        )?;
        write!(
            fm,
            "machine  : makespan {:.3} s, busy {:.1} %, {:.1} req/s, {:.0} tok/s",
            self.makespan_s,
            100.0 * self.busy_frac,
            self.throughput_qps,
            self.tokens_per_s
        )
    }
}

/// Back-of-envelope capacity: requests/s the configuration sustains
/// when every batch fills to `max_batch`, mixing tenants by weight.
/// Exact for one tenant; an upper-bound estimate for shared serving.
pub fn capacity_qps(cfg: &ArchConfig, tenants: &[Tenant], ecfg: &EngineConfig) -> f64 {
    let models = tenants.iter().map(|t| t.model.clone()).collect();
    let mut cache = CostCache::new(cfg.clone(), models, ecfg.sim.clone());
    let b = ecfg.policy.max_batch.max(1);
    let total_w: f64 = tenants.iter().map(|t| t.weight.max(0.0)).sum();
    // Mean per-request service time across the mix.
    let mut per_req = 0.0;
    for (k, t) in tenants.iter().enumerate() {
        let share = if total_w > 0.0 {
            t.weight.max(0.0) / total_w
        } else {
            1.0 / tenants.len() as f64
        };
        per_req += share * cache.cost(&[(k, b)]).seconds / b as f64;
    }
    if per_req > 0.0 {
        1.0 / per_req
    } else {
        0.0
    }
}

/// One point of a load sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub qps: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub goodput_qps: f64,
    pub completed: u64,
    pub rejected: u64,
    pub busy_frac: f64,
}

/// Load-sweep options.
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Offered rates to probe (requests/s).
    pub qps: Vec<f64>,
    /// Trace duration per point (seconds).
    pub duration_s: f64,
    /// Latency deadline for goodput.
    pub deadline_s: f64,
    /// Traffic seed (shared by every point so only the rate varies).
    pub seed: u64,
    /// Serve each tenant on its own pod partition instead of sharing.
    pub partitioned: bool,
    /// Worker threads for the sweep (`None` = `SOSA_THREADS` / machine
    /// parallelism).  Points are independent and results are merged in
    /// qps order, so the thread count never changes the output.
    pub threads: Option<usize>,
}

/// Sweep offered load over a configuration, reporting the latency/
/// goodput curve.  The saturation knee is visible as the offered rate
/// beyond which p99 diverges and goodput flattens.
///
/// Points fan out across cores; each worker carries warm
/// [`CostCache`]s across its points — one machine-wide cache in shared
/// mode, one per tenant partition in partitioned mode — so a batch
/// composition is simulated once per worker rather than once per
/// offered rate (memoization is semantically transparent — results
/// are identical with pooling and threading off, which
/// `ecfg.sim.pooling = false` + `threads = Some(1)` restores as the
/// cold baseline).  Partitions within a point run sequentially: the
/// point fan-out already saturates the workers, and nesting pools
/// would break thread pinning.
pub fn load_sweep(
    cfg: &ArchConfig,
    tenants: &[Tenant],
    ecfg: &EngineConfig,
    sweep: &SweepOptions,
) -> Result<Vec<SweepPoint>> {
    let ex = match sweep.threads {
        Some(n) => SweepExecutor::with_threads(n),
        None => SweepExecutor::new(),
    };
    let models: Vec<ModelGraph> = tenants.iter().map(|t| t.model.clone()).collect();
    // Per-worker warm caches: (shared-mode cache, per-tenant partition
    // caches).
    let init = || {
        let parts: Vec<Option<CostCache>> = (0..tenants.len()).map(|_| None).collect();
        (None::<CostCache>, parts)
    };
    let points: Vec<Result<SweepPoint>> = ex.run_with_state(
        &sweep.qps,
        init,
        |(cache, part_caches), _, &qps| {
            let spec = TrafficSpec::poisson(qps, sweep.duration_s, sweep.seed);
            let arrivals = generate(&spec, tenants);
            let rep = if sweep.partitioned {
                serve_partitioned_cached(cfg, tenants, &arrivals, ecfg, part_caches)?
            } else {
                let warm = if ecfg.sim.pooling { cache.take() } else { None };
                let c = warm.unwrap_or_else(|| {
                    CostCache::new(cfg.clone(), models.clone(), ecfg.sim.clone())
                });
                let mut engine = Engine::with_cache(cfg, tenants, c, ecfg.clone());
                let rep = engine.run(&arrivals);
                *cache = Some(engine.into_cache());
                rep
            };
            let slo = analyze(&rep, sweep.duration_s, sweep.deadline_s);
            Ok(SweepPoint {
                qps,
                p50_s: slo.latency.p50,
                p99_s: slo.latency.p99,
                goodput_qps: slo.goodput_qps,
                completed: slo.completed,
                rejected: slo.rejected,
                busy_frac: slo.busy_frac,
            })
        },
    );
    points.into_iter().collect()
}

/// Highest probed rate that served its whole offered load (no
/// admission-control shedding) with p99 inside the deadline — the max
/// sustainable QPS under the SLO, if any point qualified.  Points that
/// survive only by rejecting traffic don't count: their survivors'
/// latency looks healthy while goodput has collapsed.
pub fn max_sustainable_qps(points: &[SweepPoint], deadline_s: f64) -> Option<f64> {
    points
        .iter()
        .filter(|p| p.completed > 0 && p.rejected == 0 && p.p99_s <= deadline_s)
        .map(|p| p.qps)
        .fold(None, |acc, q| Some(acc.map_or(q, |a: f64| a.max(q))))
}

/// The standard load-probe ladder, as fractions of the estimated (or
/// requested) rate: shared by `serve --sweep` and `cluster --sweep`
/// so single-node and fleet sweep CSVs stay rate-comparable.
pub const SWEEP_LADDER: &[f64] = &[0.25, 0.5, 0.75, 0.9, 1.0, 1.1, 1.3, 1.6, 2.0];

/// Default latency deadline when the caller gives none: 5× a full
/// batch's per-request share of the estimated capacity (0.1 s when
/// capacity is unknown).  Shared by the `serve` and `cluster` CLIs and
/// the `fleet` experiment so the heuristic cannot de-sync.
pub fn default_deadline(max_batch: usize, capacity_qps: f64) -> f64 {
    if capacity_qps > 0.0 {
        5.0 * max_batch as f64 / capacity_qps
    } else {
        0.1
    }
}

/// Write sweep points as the standard sweep CSV (`qps,p50_ms,p99_ms,
/// goodput_qps,completed,rejected,busy_pct`) — one writer shared by
/// `serve --sweep` and `cluster --sweep`.
pub fn write_sweep_csv(path: impl AsRef<std::path::Path>, points: &[SweepPoint]) -> Result<()> {
    let mut csv = crate::util::CsvWriter::create(
        path,
        &["qps", "p50_ms", "p99_ms", "goodput_qps", "completed", "rejected", "busy_pct"],
    )?;
    for p in points {
        csv.row(&[
            f(p.qps, 1),
            f(p.p50_s * 1e3, 3),
            f(p.p99_s * 1e3, 3),
            f(p.goodput_qps, 1),
            p.completed.to_string(),
            p.rejected.to_string(),
            f(100.0 * p.busy_frac, 1),
        ])?;
    }
    csv.finish()
}

/// Render sweep points as the experiments' aligned table.
pub fn sweep_table(points: &[SweepPoint]) -> Table {
    let mut table = Table::new(&[
        "offered qps", "p50 ms", "p99 ms", "goodput qps", "completed", "rejected", "busy %",
    ]);
    for p in points {
        table.row(vec![
            f(p.qps, 1),
            f(p.p50_s * 1e3, 3),
            f(p.p99_s * 1e3, 3),
            f(p.goodput_qps, 1),
            p.completed.to_string(),
            p.rejected.to_string(),
            f(100.0 * p.busy_frac, 1),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::ServedRequest;

    #[test]
    fn percentile_empty_and_single() {
        // Regression: empty samples used to summarize as all-zeros —
        // a served=0 window looked like perfect latency.
        assert!(percentile(&[], 50.0).is_nan());
        assert!(percentile(&[], 99.0).is_nan());
        let one = [0.25];
        assert_eq!(percentile(&one, 0.0), 0.25);
        assert_eq!(percentile(&one, 50.0), 0.25);
        assert_eq!(percentile(&one, 99.0), 0.25);
        assert_eq!(percentile(&one, 100.0), 0.25);
        let s = LatencyStats::from_samples(&one);
        assert_eq!((s.p50, s.p95, s.p99, s.max), (0.25, 0.25, 0.25, 0.25));
        assert!(s.served());
        let empty = LatencyStats::from_samples(&[]);
        assert_eq!(empty.n, 0);
        assert!(!empty.served());
        for v in [empty.mean, empty.p50, empty.p95, empty.p99, empty.max] {
            assert!(v.is_nan(), "empty stats must be NaN, got {v}");
        }
        assert!(!(empty.p99 <= 1e9), "NaN must fail any deadline gate");
    }

    #[test]
    fn zero_request_window_reports_nan_not_zero_latency() {
        // The full analyze() path on an engine report that served
        // nothing: served=0 is explicit (n = 0, NaN percentiles), and
        // goodput/throughput stay 0 — not "p99 = 0 ms".
        let slo = analyze(&EngineReport::default(), 1.0, 0.01);
        assert_eq!(slo.completed, 0);
        assert_eq!(slo.latency.n, 0);
        assert!(slo.latency.p50.is_nan() && slo.latency.p99.is_nan());
        assert_eq!(slo.goodput_qps, 0.0);
        let text = format!("{slo}");
        assert!(text.contains("NaN"), "empty window must render NaN: {text}");
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 95.0), 95.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        // Two samples: p50 is the first, p99 the second.
        assert_eq!(percentile(&[1.0, 2.0], 50.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 99.0), 2.0);
    }

    #[test]
    fn analyze_counts_goodput_against_deadline() {
        let mk = |t_arrival: f64, t_end: f64| ServedRequest {
            id: 0,
            tenant: 0,
            batch: 1,
            t_arrival,
            t_start: t_arrival,
            t_end,
        };
        let rep = EngineReport {
            completed: vec![mk(0.0, 0.010), mk(0.1, 0.115), mk(0.2, 0.290)],
            rejected: 1,
            rejected_by_tenant: vec![1],
            makespan_s: 0.290,
            busy_s: 0.1,
            batches: 3,
            total_ops: 300,
            sim_calls: 1,
            group_stats: vec![],
        };
        let slo = analyze(&rep, 1.0, 0.020);
        assert_eq!(slo.offered, 4);
        assert_eq!(slo.completed, 3);
        assert_eq!(slo.within_deadline, 2, "10 ms and 15 ms meet 20 ms");
        assert!((slo.goodput_qps - 2.0).abs() < 1e-12);
        assert!((slo.throughput_qps - 3.0).abs() < 1e-12);
        assert!((slo.latency.max - 0.09).abs() < 1e-12);
    }

    #[test]
    fn display_is_stable() {
        let slo = analyze(&EngineReport::default(), 1.0, 0.01);
        let a = format!("{slo}");
        let b = format!("{slo}");
        assert_eq!(a, b);
        assert!(a.contains("p99"));
    }

    #[test]
    fn max_sustainable_picks_last_meeting_deadline() {
        let mk = |qps: f64, p99: f64| SweepPoint {
            qps,
            p50_s: p99 / 2.0,
            p99_s: p99,
            goodput_qps: qps,
            completed: 100,
            rejected: 0,
            busy_frac: 0.5,
        };
        let pts = vec![mk(100.0, 0.005), mk(200.0, 0.008), mk(400.0, 0.5)];
        assert_eq!(max_sustainable_qps(&pts, 0.01), Some(200.0));
        assert_eq!(max_sustainable_qps(&pts, 1e-4), None);
        assert_eq!(max_sustainable_qps(&[], 0.01), None);
    }

    #[test]
    fn autoreg_goodput_requires_both_deadlines() {
        use crate::serve::autoreg::{AutoregReport, ServedDecode};
        let served = |id: u64, ttft: f64, tpot: f64, steps: usize| ServedDecode {
            id,
            t_arrival: 0.0,
            t_first_token: ttft,
            t_end: ttft + tpot * (steps - 1) as f64,
            prefill_tokens: 16,
            decode_steps: steps,
            evictions: 0,
        };
        let rep = AutoregReport {
            // fast ttft + fast tpot / fast + slow / slow + fast.
            completed: vec![
                served(0, 0.001, 0.0001, 5),
                served(1, 0.001, 0.0200, 5),
                served(2, 0.500, 0.0001, 5),
            ],
            rejected: 1,
            generated_tokens: 15,
            makespan_s: 2.0,
            busy_s: 1.0,
            ..AutoregReport::default()
        };
        let slo = analyze_autoreg(&rep, 1.0, 0.01, 0.001);
        assert_eq!(slo.offered, 4);
        assert_eq!(slo.completed, 3);
        assert_eq!(slo.rejected, 1);
        assert_eq!(slo.within_ttft, 2);
        assert_eq!(slo.within_tpot, 2);
        assert_eq!(slo.within_both, 1, "goodput needs ttft AND tpot in deadline");
        // Span extends to the 2 s makespan.
        assert_eq!(slo.goodput_qps, 0.5);
        assert_eq!(slo.throughput_qps, 1.5);
        assert_eq!(slo.tokens_per_s, 7.5);
        assert_eq!(slo.busy_frac, 0.5);
        assert_eq!(slo.ttft.n, 3);
        assert_eq!(slo.tpot.n, 3);
        let text = slo.to_string();
        assert!(text.contains("ttft"), "{text}");
        assert!(text.contains("tpot"), "{text}");
        assert!(text.contains("goodput"), "{text}");
    }

    #[test]
    fn single_token_requests_have_zero_tpot() {
        use crate::serve::autoreg::ServedDecode;
        let s = ServedDecode {
            id: 0,
            t_arrival: 0.0,
            t_first_token: 0.5,
            t_end: 0.5,
            prefill_tokens: 8,
            decode_steps: 1,
            evictions: 0,
        };
        assert_eq!(s.ttft_s(), 0.5);
        assert_eq!(s.tpot_s(), 0.0, "no inter-token gap with one token");
    }
}
