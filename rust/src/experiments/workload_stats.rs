//! Fig. 4 (workload dimension distributions) and Fig. 5 (iso-power
//! design-space exploration heatmaps).

use super::ExpOptions;
use crate::analytic::dse_cell;
use crate::power::TDP_W;
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::zoo;
use crate::Result;

/// Fig. 4: ops-weighted p10/mean/p90 of filter reuse (m), features (k)
/// and filters (n) for every benchmark.
pub fn fig4(opts: &ExpOptions) -> Result<()> {
    let mut csv = CsvWriter::create(
        format!("{}/fig4.csv", opts.out_dir),
        &["model", "dim", "p10", "mean", "p90"],
    )?;
    let mut table = Table::new(&["model", "reuse p10/mean/p90", "features", "filters"]);
    let mut cnn_reuse = 0.0;
    let mut cnn_filters = 0.0;
    let mut cnn_n = 0.0;
    let mut bert_reuse = 0.0;
    let mut bert_filters = 0.0;
    let mut bert_n = 0.0;
    for m in zoo::benchmarks() {
        let reuse = m.dim_percentiles(|o| o.m);
        let feats = m.dim_percentiles(|o| o.k);
        let filts = m.dim_percentiles(|o| o.n);
        for (dim, s) in [("reuse", reuse), ("features", feats), ("filters", filts)] {
            csv.row(&[
                m.name.clone(),
                dim.into(),
                s.p10.to_string(),
                f(s.mean, 1),
                s.p90.to_string(),
            ])?;
        }
        table.row(vec![
            m.name.clone(),
            format!("{}/{:.0}/{}", reuse.p10, reuse.mean, reuse.p90),
            format!("{}/{:.0}/{}", feats.p10, feats.mean, feats.p90),
            format!("{}/{:.0}/{}", filts.p10, filts.mean, filts.p90),
        ]);
        if m.name.starts_with("BERT") {
            bert_reuse += reuse.mean;
            bert_filters += filts.mean;
            bert_n += 1.0;
        } else {
            cnn_reuse += reuse.mean;
            cnn_filters += filts.mean;
            cnn_n += 1.0;
        }
    }
    csv.finish()?;
    println!("{table}");
    let reuse_ratio = (cnn_reuse / cnn_n) / (bert_reuse / bert_n);
    let filt_ratio = (bert_filters / bert_n) / (cnn_filters / cnn_n);
    println!("CNN/BERT filter-reuse ratio : {reuse_ratio:.1}x  (paper: ~15x)");
    println!("BERT/CNN filter-count ratio : {filt_ratio:.1}x  (paper: ~6x)");
    Ok(())
}

/// Fig. 5: effective TOps/s/W heatmaps for CNN-only, BERT-only and
/// mixed workload sets over (r, c) grids at iso-power (400 W).
pub fn fig5(opts: &ExpOptions) -> Result<()> {
    let dims: Vec<usize> = if opts.quick {
        vec![8, 16, 32, 64, 128, 256]
    } else {
        vec![8, 16, 20, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512]
    };
    let cnns = zoo::fig5_cnns();
    let berts = zoo::fig5_berts();
    let mixed: Vec<_> = cnns.iter().cloned().chain(berts.iter().cloned()).collect();
    for (tag, models) in [("cnn", &cnns), ("bert", &berts), ("mixed", &mixed)] {
        let mut csv = CsvWriter::create(
            format!("{}/fig5_{tag}.csv", opts.out_dir),
            &["r", "c", "pods", "utilization", "eff_tops", "eff_tops_per_watt"],
        )?;
        let mut best = (0usize, 0usize, f64::MIN);
        for &r in &dims {
            for &c in &dims {
                let cell = dse_cell(r, c, models, TDP_W);
                csv.row(&[
                    r.to_string(),
                    c.to_string(),
                    cell.pods.to_string(),
                    f(cell.utilization, 4),
                    f(cell.eff_tops, 2),
                    f(cell.eff_tops_per_watt, 4),
                ])?;
                if cell.eff_tops_per_watt > best.2 {
                    best = (r, c, cell.eff_tops_per_watt);
                }
            }
        }
        csv.finish()?;
        let paper = match tag {
            "cnn" => "66x32",
            "bert" => "20x128",
            _ => "20x32 (32x32 chosen)",
        };
        println!("fig5 {tag}: optimum {}x{} at {:.3} TOps/s/W (paper: {paper})",
                 best.0, best.1, best.2);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_runs_and_reports_ratios() {
        let opts = ExpOptions {
            out_dir: std::env::temp_dir().join("sosa_fig4").to_str().unwrap().into(),
            quick: true,
        };
        fig4(&opts).unwrap();
        let csv = std::fs::read_to_string(format!("{}/fig4.csv", opts.out_dir)).unwrap();
        assert!(csv.lines().count() > 30); // 10 models × 3 dims + header
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
