//! Table 1 (interconnect metrics) and Fig. 12a (effective throughput
//! vs TDP per interconnect type), declared as [`DesignSpace`] sweeps
//! over the interconnect axis.  Points differing only in fabric share
//! one compiled artifact per evaluator worker (the explore cache's
//! form of fig12a's compile-once reuse).  Outputs are byte-identical
//! to the pre-`explore` loops (`tests/golden.rs`).

use super::ExpOptions;
use crate::arch::ArrayDims;
use crate::explore::{DesignSpace, Explorer};
use crate::interconnect::cost::{interconnect_power_w, PodTraffic};
use crate::interconnect::Kind;
use crate::power::peak_power;
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::zoo;
use crate::Result;

/// The interconnects of Table 1, with the paper's reference metrics.
pub const KINDS: &[(Kind, f64, f64, f64)] = &[
    // (kind, paper busy %, paper cycles/tile-op, paper mW/byte)
    (Kind::Butterfly { expansion: 1 }, 66.81, 19.72, 0.23),
    (Kind::Butterfly { expansion: 2 }, 72.41, 20.17, 0.52),
    (Kind::Butterfly { expansion: 4 }, 72.26, 20.27, 1.15),
    (Kind::Butterfly { expansion: 8 }, 72.43, 20.48, 2.53),
    (Kind::Crossbar, 72.38, 19.73, 7.36),
    (Kind::Benes, 72.38, 30.00, 0.92),
];

/// The Table 1 design space — the exact (interconnect × benchmark)
/// grid `table1` sweeps on its 16×16 / 256-pod geometry, records
/// kind-major in [`KINDS`] order.  Public for the two-tier
/// certification tests.
pub fn table1_space(quick: bool) -> DesignSpace {
    let names = if quick {
        vec!["resnet50", "bert-base"]
    } else {
        vec!["inception", "resnet50", "densenet121", "bert-medium", "bert-base"]
    };
    let benches: Vec<_> = names.iter().map(|n| zoo::by_name(n).unwrap()).collect();
    let kinds: Vec<Kind> = KINDS.iter().map(|&(k, _, _, _)| k).collect();
    DesignSpace::baseline()
        .arrays(&[ArrayDims::new(16, 16)])
        .pods(&[256])
        .interconnects(&kinds)
        .workloads(benches)
}

/// Table 1: busy pods / cycles per tile op / mW per byte, per
/// interconnect, averaged across workloads (the paper's context —
/// matching its ~20-cycle tile ops — is a 16×16 array).
pub fn table1(opts: &ExpOptions) -> Result<()> {
    let n_bench = if opts.quick { 2 } else { 5 };
    let pods = 256usize;
    let mut csv = CsvWriter::create(
        format!("{}/table1.csv", opts.out_dir),
        &["interconnect", "busy_pct", "cycles_per_tile_op", "mw_per_byte",
          "paper_busy", "paper_cycles", "paper_mw"],
    )?;
    let mut table = Table::new(&[
        "type", "busy %", "cyc/op", "mW/B", "paper busy", "paper cyc", "paper mW",
    ]);
    // Declarative (interconnect × benchmark) grid on a 16×16 / 256-pod
    // geometry; records are kind-major in KINDS order.
    let x = Explorer::new().evaluate(&table1_space(opts.quick))?;
    for (ki, &(kind, p_busy, p_cyc, p_mw)) in KINDS.iter().enumerate() {
        let recs = &x.records[ki * n_bench..(ki + 1) * n_bench];
        let busy = 100.0
            * recs.iter().map(|r| r.stats.busy_pods_frac(&r.point.cfg)).sum::<f64>()
            / n_bench as f64;
        let cyc =
            recs.iter().map(|r| r.stats.cycles_per_tile_op()).sum::<f64>() / n_bench as f64;
        let mw = kind.mw_per_byte(pods);
        csv.row(&[kind.to_string(), f(busy, 2), f(cyc, 2), f(mw, 2),
                  f(p_busy, 2), f(p_cyc, 2), f(p_mw, 2)])?;
        table.row(vec![
            kind.to_string(), format!("{busy:.1}"), format!("{cyc:.1}"),
            format!("{mw:.2}"), format!("{p_busy}"), format!("{p_cyc}"),
            format!("{p_mw}"),
        ]);
    }
    csv.finish()?;
    println!("{table}");
    Ok(())
}

/// Fig. 12a's interconnect axis (all five topology families).
pub fn fig12a_kinds() -> Vec<Kind> {
    vec![
        Kind::Butterfly { expansion: 1 },
        Kind::Butterfly { expansion: 2 },
        Kind::Butterfly { expansion: 4 },
        Kind::Benes,
        Kind::Crossbar,
        Kind::Mesh,
        Kind::HTree,
    ]
}

/// The Fig. 12a design space — the exact (pods × interconnect ×
/// benchmark) grid `fig12a` sweeps at 32×32.  Public for the two-tier
/// certification tests and `benches/explore.rs`.
pub fn fig12a_space(quick: bool) -> DesignSpace {
    let pods_sweep: Vec<usize> = if quick { vec![64, 256] } else { vec![32, 64, 128, 256] };
    let names = if quick {
        vec!["resnet50"]
    } else {
        vec!["resnet50", "bert-base", "densenet121"]
    };
    let benches: Vec<_> = names.iter().map(|n| zoo::by_name(n).unwrap()).collect();
    DesignSpace::baseline()
        .square_arrays(&[32])
        .pods(&pods_sweep)
        .interconnects(&fig12a_kinds())
        .workloads(benches)
}

/// Fig. 12a: effective throughput vs TDP for each interconnect as pods
/// scale 32..256 (plus expansion-factor sensitivity, Fig. 12b-left).
pub fn fig12a(opts: &ExpOptions) -> Result<()> {
    let kinds = fig12a_kinds();
    let pods_sweep: Vec<usize> =
        if opts.quick { vec![64, 256] } else { vec![32, 64, 128, 256] };
    let n_bench = if opts.quick { 1 } else { 3 };
    let mut csv = CsvWriter::create(
        format!("{}/fig12a.csv", opts.out_dir),
        &["interconnect", "pods", "tdp_w", "eff_tops", "icn_power_w"],
    )?;
    let mut table = Table::new(&["type", "pods", "TDP W", "eff TOps/s", "icn W"]);
    // Declarative (pods × interconnect × benchmark) grid at 32×32.
    // A Global-spec artifact is geometry-bound but interconnect-
    // agnostic, so the evaluator's warm compiled cache pays the
    // compile phase at most once per (pods × benchmark) key *per
    // worker* and re-executes across fabrics — bounded-duplicate
    // compilation versus the hand-rolled sweep's single global
    // compile (`SweepExecutor::run_compiled`), in exchange for the
    // whole grid (not just execution) fanning across cores.
    let x = Explorer::new().evaluate(&fig12a_space(opts.quick))?;
    let rec = |pi: usize, ki: usize, bi: usize| {
        &x.records[(pi * kinds.len() + ki) * n_bench + bi]
    };
    for (ki, &kind) in kinds.iter().enumerate() {
        for (pi, &pods) in pods_sweep.iter().enumerate() {
            let cfg = &rec(pi, ki, 0).point.cfg;
            let util = (0..n_bench)
                .map(|bi| rec(pi, ki, bi).utilization)
                .sum::<f64>()
                / n_bench as f64;
            let tdp = peak_power(cfg).total();
            // Fig. 12a plots effective throughput of the *provisioned*
            // silicon against its own TDP (not normalized to 400 W).
            let eff = util * cfg.peak_ops() / 1e12;
            let icn_w = interconnect_power_w(
                kind, pods, PodTraffic::steady_state(32, 32, cfg.precision), 1.0);
            csv.row(&[kind.to_string(), pods.to_string(), f(tdp, 1), f(eff, 1),
                      f(icn_w, 1)])?;
            table.row(vec![kind.to_string(), pods.to_string(),
                           format!("{tdp:.0}"), format!("{eff:.1}"),
                           format!("{icn_w:.1}")]);
        }
    }
    csv.finish()?;
    println!("{table}");
    println!("paper: Butterfly-2 within ~4% of Crossbar at 2.3x less \
              interconnect power; Benes degrades as pods scale; k>2 gains <2%.");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_cover_table1() {
        assert_eq!(KINDS.len(), 6);
    }
}
