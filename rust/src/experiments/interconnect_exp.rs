//! Table 1 (interconnect metrics) and Fig. 12a (effective throughput
//! vs TDP per interconnect type).

use super::ExpOptions;
use crate::arch::{ArchConfig, ArrayDims};
use crate::interconnect::cost::{interconnect_power_w, PodTraffic};
use crate::interconnect::Kind;
use crate::power::{peak_power, throughput_at_tdp, TDP_W};
use crate::sim::{simulate_with, SimOptions, SweepExecutor};
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::zoo;
use crate::Result;

/// The interconnects of Table 1, with the paper's reference metrics.
pub const KINDS: &[(Kind, f64, f64, f64)] = &[
    // (kind, paper busy %, paper cycles/tile-op, paper mW/byte)
    (Kind::Butterfly { expansion: 1 }, 66.81, 19.72, 0.23),
    (Kind::Butterfly { expansion: 2 }, 72.41, 20.17, 0.52),
    (Kind::Butterfly { expansion: 4 }, 72.26, 20.27, 1.15),
    (Kind::Butterfly { expansion: 8 }, 72.43, 20.48, 2.53),
    (Kind::Crossbar, 72.38, 19.73, 7.36),
    (Kind::Benes, 72.38, 30.00, 0.92),
];

/// Table 1: busy pods / cycles per tile op / mW per byte, per
/// interconnect, averaged across workloads (the paper's context —
/// matching its ~20-cycle tile ops — is a 16×16 array).
pub fn table1(opts: &ExpOptions) -> Result<()> {
    let names = if opts.quick {
        vec!["resnet50", "bert-base"]
    } else {
        vec!["inception", "resnet50", "densenet121", "bert-medium", "bert-base"]
    };
    let benches: Vec<_> = names.iter().map(|n| zoo::by_name(n).unwrap()).collect();
    let pods = 256usize;
    let mut csv = CsvWriter::create(
        format!("{}/table1.csv", opts.out_dir),
        &["interconnect", "busy_pct", "cycles_per_tile_op", "mw_per_byte",
          "paper_busy", "paper_cycles", "paper_mw"],
    )?;
    let mut table = Table::new(&[
        "type", "busy %", "cyc/op", "mW/B", "paper busy", "paper cyc", "paper mW",
    ]);
    // Fan the (interconnect × benchmark) grid across cores with one
    // pooled context per worker; rows assemble in KINDS order below.
    let sim_opts = SimOptions::default();
    let cfgs: Vec<ArchConfig> = KINDS
        .iter()
        .map(|&(kind, _, _, _)| {
            let mut cfg = ArchConfig::with_array(ArrayDims::new(16, 16), pods);
            cfg.interconnect = kind;
            cfg
        })
        .collect();
    let grid: Vec<(usize, usize)> = (0..KINDS.len())
        .flat_map(|ki| (0..benches.len()).map(move |bi| (ki, bi)))
        .collect();
    let cells: Vec<(f64, f64)> = SweepExecutor::new().run_with_ctx(&grid, |ctx, _, &(ki, bi)| {
        let s = simulate_with(ctx, &cfgs[ki], &benches[bi], &sim_opts);
        (s.busy_pods_frac(&cfgs[ki]), s.cycles_per_tile_op())
    });
    for (ki, &(kind, p_busy, p_cyc, p_mw)) in KINDS.iter().enumerate() {
        let per_bench = &cells[ki * benches.len()..(ki + 1) * benches.len()];
        let busy =
            100.0 * per_bench.iter().map(|&(b, _)| b).sum::<f64>() / benches.len() as f64;
        let cyc = per_bench.iter().map(|&(_, c)| c).sum::<f64>() / benches.len() as f64;
        let mw = kind.mw_per_byte(pods);
        csv.row(&[kind.to_string(), f(busy, 2), f(cyc, 2), f(mw, 2),
                  f(p_busy, 2), f(p_cyc, 2), f(p_mw, 2)])?;
        table.row(vec![
            kind.to_string(), format!("{busy:.1}"), format!("{cyc:.1}"),
            format!("{mw:.2}"), format!("{p_busy}"), format!("{p_cyc}"),
            format!("{p_mw}"),
        ]);
    }
    csv.finish()?;
    println!("{table}");
    Ok(())
}

/// Fig. 12a: effective throughput vs TDP for each interconnect as pods
/// scale 32..256 (plus expansion-factor sensitivity, Fig. 12b-left).
pub fn fig12a(opts: &ExpOptions) -> Result<()> {
    let kinds: Vec<Kind> = vec![
        Kind::Butterfly { expansion: 1 },
        Kind::Butterfly { expansion: 2 },
        Kind::Butterfly { expansion: 4 },
        Kind::Benes,
        Kind::Crossbar,
        Kind::Mesh,
        Kind::HTree,
    ];
    let pods_sweep: Vec<usize> =
        if opts.quick { vec![64, 256] } else { vec![32, 64, 128, 256] };
    let names = if opts.quick {
        vec!["resnet50"]
    } else {
        vec!["resnet50", "bert-base", "densenet121"]
    };
    let benches: Vec<_> = names.iter().map(|n| zoo::by_name(n).unwrap()).collect();
    let mut csv = CsvWriter::create(
        format!("{}/fig12a.csv", opts.out_dir),
        &["interconnect", "pods", "tdp_w", "eff_tops", "icn_power_w"],
    )?;
    let mut table = Table::new(&["type", "pods", "TDP W", "eff TOps/s", "icn W"]);
    // Compile once per (pod count × benchmark) — a Global-spec artifact
    // is geometry-bound but interconnect-agnostic — then fan execution
    // of each compiled artifact across every interconnect variant
    // (`SweepExecutor::run_compiled`): the sweep pays the compile phase
    // |pods|×|benches| times instead of ×|kinds| more.
    let sim_opts = SimOptions::default();
    let cfg_for = |kind: Kind, pods: usize| {
        let mut cfg = ArchConfig::with_array(ArrayDims::new(32, 32), pods);
        cfg.interconnect = kind;
        cfg
    };
    let ex = SweepExecutor::new();
    let mut ctx = crate::sim::SimContext::new();
    // cells[pi·|benches| + bi][ki] = utilization of bench bi on kind ki.
    let mut cells: Vec<Vec<f64>> = Vec::with_capacity(pods_sweep.len() * benches.len());
    for &pods in &pods_sweep {
        let kind_cfgs: Vec<ArchConfig> =
            kinds.iter().map(|&kind| cfg_for(kind, pods)).collect();
        for bench in &benches {
            let cp = crate::compile::compile_with(&mut ctx, &kind_cfgs[0], bench, &sim_opts);
            let stats = ex.run_compiled(&cp, &kind_cfgs, &sim_opts);
            cells.push(
                stats.iter().zip(&kind_cfgs).map(|(s, c)| s.utilization(c)).collect(),
            );
        }
    }
    for (ki, &kind) in kinds.iter().enumerate() {
        for (pi, &pods) in pods_sweep.iter().enumerate() {
            let cfg = &cfg_for(kind, pods);
            let util = (0..benches.len())
                .map(|bi| cells[pi * benches.len() + bi][ki])
                .sum::<f64>()
                / benches.len() as f64;
            let tdp = peak_power(cfg).total();
            // Fig. 12a plots effective throughput of the *provisioned*
            // silicon against its own TDP (not normalized to 400 W).
            let eff = util * cfg.peak_ops() / 1e12;
            let icn_w = interconnect_power_w(
                kind, pods, PodTraffic::steady_state(32, 32, cfg.precision), 1.0);
            csv.row(&[kind.to_string(), pods.to_string(), f(tdp, 1), f(eff, 1),
                      f(icn_w, 1)])?;
            table.row(vec![kind.to_string(), pods.to_string(),
                           format!("{tdp:.0}"), format!("{eff:.1}"),
                           format!("{icn_w:.1}")]);
        }
    }
    csv.finish()?;
    println!("{table}");
    println!("paper: Butterfly-2 within ~4% of Crossbar at 2.3x less \
              interconnect power; Benes degrades as pods scale; k>2 gains <2%.");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_cover_table1() {
        assert_eq!(KINDS.len(), 6);
    }
}
