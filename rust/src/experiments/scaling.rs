//! Fig. 10 (effective throughput vs TDP / pod scaling) and Fig. 11
//! (batch-size and multi-tenancy scaling).  Fig. 10 is declared as two
//! [`DesignSpace`] sweeps — the SOSA (array × pods) grid and the
//! monolithic ladder — with byte-identical output to the hand-rolled
//! loops.

use super::ExpOptions;
use crate::arch::presets;
use crate::coordinator::{Coordinator, Request};
use crate::explore::{DesignSpace, Explorer};
use crate::power::peak_power;
use crate::sim::{simulate, simulate_multi, SimOptions};
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::zoo;
use crate::Result;

/// The two Fig. 10 design spaces — `(sosa_grid, monolithic_ladder)`,
/// the exact sweeps `fig10` evaluates.  Public for the two-tier
/// certification tests.
pub fn fig10_spaces(quick: bool) -> (DesignSpace, DesignSpace) {
    let names = if quick {
        vec!["resnet152"]
    } else {
        vec!["resnet50", "resnet152", "bert-base"]
    };
    let benches: Vec<_> = names.iter().map(|n| zoo::by_name(n).unwrap()).collect();
    let pod_sweep: Vec<usize> = if quick { vec![64, 256] } else { vec![32, 64, 128, 256, 512] };
    let sosa = DesignSpace::baseline()
        .square_arrays(&[32, 64])
        .pods(&pod_sweep)
        .workloads(benches.clone());
    let mono_dims: Vec<usize> = if quick { vec![512] } else { vec![400, 512, 640, 768, 1024] };
    let mono = DesignSpace::baseline()
        .square_arrays(&mono_dims)
        .pods(&[1])
        .workloads(benches);
    (sosa, mono)
}

/// Fig. 10: effective throughput as the pod count (and hence TDP)
/// scales, for SOSA 32×32 / 64×64 and the monolithic baseline.
pub fn fig10(opts: &ExpOptions) -> Result<()> {
    let n_bench = if opts.quick { 1 } else { 3 };
    let mut csv = CsvWriter::create(
        format!("{}/fig10.csv", opts.out_dir),
        &["design", "pods_or_dim", "tdp_w", "eff_tops"],
    )?;
    let mut table = Table::new(&["design", "pods/dim", "TDP W", "eff TOps/s"]);

    // One row per (space point ÷ benchmarks): average utilization,
    // then effective throughput of the provisioned silicon vs its own
    // TDP.
    let mut emit = |tag: &str,
                    label: String,
                    recs: &[crate::explore::EvalRecord]|
     -> Result<()> {
        let cfg = &recs[0].point.cfg;
        let util = recs.iter().map(|r| r.utilization).sum::<f64>() / n_bench as f64;
        let tdp = peak_power(cfg).total();
        let eff = util * cfg.peak_ops() / 1e12;
        csv.row(&[tag.into(), label.clone(), f(tdp, 1), f(eff, 1)])?;
        table.row(vec![tag.into(), label, format!("{tdp:.0}"), format!("{eff:.1}")]);
        Ok(())
    };

    let pod_sweep: Vec<usize> =
        if opts.quick { vec![64, 256] } else { vec![32, 64, 128, 256, 512] };
    let mono_dims: Vec<usize> =
        if opts.quick { vec![512] } else { vec![400, 512, 640, 768, 1024] };
    // SOSA grid: (32×32, 64×64) × pod ladder, benchmarks inner.
    let (sosa, mono) = fig10_spaces(opts.quick);
    let x = Explorer::new().evaluate(&sosa)?;
    for (gi, &tag) in ["SOSA-32x32", "SOSA-64x64"].iter().enumerate() {
        for (pi, &pods) in pod_sweep.iter().enumerate() {
            let base = (gi * pod_sweep.len() + pi) * n_bench;
            emit(tag, pods.to_string(), &x.records[base..base + n_bench])?;
        }
    }
    // Monolithic baseline: one array, dims 400..1024 (paper's range).
    let x = Explorer::new().evaluate(&mono)?;
    for (di, &dim) in mono_dims.iter().enumerate() {
        let base = di * n_bench;
        emit("Monolithic", dim.to_string(), &x.records[base..base + n_bench])?;
    }
    csv.finish()?;
    println!("{table}");
    println!("paper: SOSA-32x32 outperforms up to 1.5x above ~90 W; gains \
              saturate beyond ~128 pods at batch 1 (insufficient tile ops).");
    Ok(())
}

/// Fig. 11: effective throughput vs batch size for ResNet-152 only,
/// BERT-medium only, and both in parallel (multi-tenancy).
pub fn fig11(opts: &ExpOptions) -> Result<()> {
    let cfg = presets::by_name("baseline").expect("registered preset");
    let sim_opts = SimOptions::default();
    let resnet = zoo::by_name("resnet152").unwrap();
    let bert = zoo::by_name("bert-medium").unwrap();
    let batches: Vec<usize> = if opts.quick { vec![1, 4] } else { vec![1, 2, 4, 8] };
    let mut csv = CsvWriter::create(
        format!("{}/fig11.csv", opts.out_dir),
        &["workload", "batch", "eff_tops"],
    )?;
    let mut table = Table::new(&["workload", "batch", "eff TOps/s"]);
    for &b in &batches {
        let r = simulate(&cfg, &resnet.with_batch(b), &sim_opts);
        let s = simulate(&cfg, &bert.with_batch(b), &sim_opts);
        let both = simulate_multi(
            &cfg,
            &[&resnet.with_batch(b), &bert.with_batch(b)],
            &sim_opts,
        );
        for (tag, st) in [("resnet152", &r), ("bert-medium", &s), ("both", &both)] {
            let eff = st.achieved_ops(&cfg) / 1e12;
            csv.row(&[tag.into(), b.to_string(), f(eff, 1)])?;
            table.row(vec![tag.into(), b.to_string(), format!("{eff:.1}")]);
        }
    }
    csv.finish()?;
    println!("{table}");

    // §6.1 headline: parallel vs sequential at batch 1 (via the
    // coordinator, which is the serving-path implementation).
    let reqs = vec![Request::new(0, resnet, 1), Request::new(1, bert, 1)];
    let multi = Coordinator::new(cfg.clone()).serve(&reqs);
    let single = Coordinator::new(cfg).single_tenant().serve(&reqs);
    let gain = multi.achieved_ops / single.achieved_ops;
    println!("multi-tenancy gain at batch 1: {gain:.2}x (paper: 1.44x; \
              parallel 397 TOps/s)");
    println!("  parallel  : {:.1} TOps/s", multi.achieved_ops / 1e12);
    println!("  sequential: {:.1} TOps/s", single.achieved_ops / 1e12);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_quick_runs() {
        let dir = std::env::temp_dir().join("sosa_fig11");
        let opts = ExpOptions { out_dir: dir.to_str().unwrap().into(), quick: true };
        fig11(&opts).unwrap();
        assert!(dir.join("fig11.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
