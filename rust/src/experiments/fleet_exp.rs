//! The `fleet` experiment: goodput-vs-node-count scaling at fleet
//! scale — how many accelerators does the §5 workload mix need?
//!
//! A fixed offered load (sized to saturate even the largest probed
//! fleet) is served by fleets of growing node count under two dispatch
//! policies (round-robin and join-shortest-queue).  Goodput should
//! scale close to linearly with node count until the offered rate is
//! covered — the fleet-level analogue of the paper's intra-chip
//! scale-out argument.  Output: `fleet.csv`
//! (nodes × policy × goodput/latency/power rows), pinned byte-for-byte
//! by `tests/golden.rs` like the §6 experiment CSVs.

use super::ExpOptions;
use crate::arch::{ArchConfig, ArrayDims};
use crate::cluster::{analyze_fleet, Fleet, FleetConfig, Policy};
use crate::serve::{default_deadline, generate, BatchPolicy, EngineConfig, Tenant, TrafficSpec};
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::{bert::bert_named, zoo};
use crate::Result;

/// The workload mix the fleet serves: the full run uses the §5
/// CNN + BERT pairing (resnet50 + bert-base, the paper's multi-tenant
/// mix); quick mode keeps the same two-tenant shape with the Fig. 5
/// BERT-mini/small stand-ins so the CI-sized sweep stays fast (a
/// 299-input CNN tiled on the quick node would dominate the suite's
/// runtime without exercising anything extra).
fn mix(quick: bool) -> Vec<Tenant> {
    if quick {
        vec![
            Tenant::new(bert_named("mini", 100), 1.0),
            Tenant::new(bert_named("small", 100), 1.0),
        ]
    } else {
        vec![
            Tenant::new(zoo::by_name("resnet50").expect("zoo model"), 1.0),
            Tenant::new(zoo::by_name("bert-base").expect("zoo model"), 1.0),
        ]
    }
}

/// Per-node architecture (quick shrinks the node, not the logic).
fn node_config(quick: bool) -> ArchConfig {
    if quick {
        ArchConfig::with_array(ArrayDims::new(16, 16), 16)
    } else {
        ArchConfig::with_array(ArrayDims::new(32, 32), 64)
    }
}

/// Build the fleet for one row.
fn fleet_for(n: usize, policy: Policy, quick: bool) -> Result<Fleet> {
    Fleet::homogeneous(
        n,
        node_config(quick),
        FleetConfig {
            policy,
            engine: EngineConfig {
                policy: BatchPolicy {
                    max_batch: if quick { 4 } else { 8 },
                    max_wait_s: 2e-3,
                },
                ..Default::default()
            },
            ..Default::default()
        },
    )
}

/// Run the fleet scaling experiment.
pub fn fleet(opts: &ExpOptions) -> Result<()> {
    let counts: Vec<usize> = if opts.quick { vec![1, 2, 4] } else { vec![1, 2, 4, 8] };
    let duration_s = if opts.quick { 0.05 } else { 0.5 };
    let seed = 42u64;
    let tenants = mix(opts.quick);

    // Offered load: 1.2× the largest probed fleet's estimated
    // capacity, held fixed across every row so goodput growth comes
    // from added nodes, not added traffic.  Deterministic (capacity is
    // a pure function of the configuration).
    let max_nodes = *counts.last().expect("non-empty counts");
    let probe = fleet_for(max_nodes, Policy::RoundRobin, opts.quick)?;
    let node_cap = probe.capacity_qps(&tenants) / probe.len() as f64;
    let offered = 1.2 * node_cap * max_nodes as f64;
    // Deadline: 5× a full batch's per-request share of one node.
    let max_batch = if opts.quick { 4 } else { 8 };
    let deadline_s = default_deadline(max_batch, node_cap);

    let mut csv = CsvWriter::create(
        format!("{}/fleet.csv", opts.out_dir),
        &["nodes", "policy", "offered_qps", "p50_ms", "p99_ms", "goodput_qps",
          "completed", "rejected", "busy_pct", "fleet_peak_w", "eff_tops"],
    )?;
    let mut table = Table::new(&[
        "nodes", "policy", "offered", "p50 ms", "p99 ms", "goodput", "busy %",
        "fleet W", "TOps/s",
    ]);
    // One trace for every row: the spec is row-invariant and
    // generation is seed-deterministic.
    let arrivals = generate(&TrafficSpec::poisson(offered, duration_s, seed), &tenants);
    for &n in &counts {
        for policy in [Policy::RoundRobin, Policy::JoinShortestQueue] {
            let fleet = fleet_for(n, policy.clone(), opts.quick)?;
            let rep = fleet.serve(&tenants, &arrivals)?;
            let slo = analyze_fleet(&fleet, &rep, duration_s, deadline_s);
            csv.row(&[
                n.to_string(),
                policy.name().to_string(),
                f(offered, 1),
                f(slo.slo.latency.p50 * 1e3, 3),
                f(slo.slo.latency.p99 * 1e3, 3),
                f(slo.slo.goodput_qps, 1),
                slo.slo.completed.to_string(),
                slo.slo.rejected.to_string(),
                f(100.0 * slo.slo.busy_frac, 1),
                f(slo.fleet_peak_w, 1),
                f(slo.eff_tops, 2),
            ])?;
            table.row(vec![
                n.to_string(),
                policy.name().to_string(),
                format!("{offered:.0}"),
                format!("{:.3}", slo.slo.latency.p50 * 1e3),
                format!("{:.3}", slo.slo.latency.p99 * 1e3),
                format!("{:.1}", slo.slo.goodput_qps),
                format!("{:.1}", 100.0 * slo.slo.busy_frac),
                format!("{:.1}", slo.fleet_peak_w),
                format!("{:.2}", slo.eff_tops),
            ]);
        }
    }
    csv.finish()?;
    println!("{table}");
    println!(
        "offered {offered:.0} req/s fixed across rows (1.2x the {max_nodes}-node \
         fleet's estimated capacity); goodput should grow with node count"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fleet_experiment_writes_csv() {
        let dir = std::env::temp_dir().join("sosa_fleet_exp");
        std::fs::remove_dir_all(&dir).ok();
        let opts = ExpOptions { out_dir: dir.to_str().unwrap().into(), quick: true };
        fleet(&opts).unwrap();
        let text = std::fs::read_to_string(dir.join("fleet.csv")).unwrap();
        assert!(text.starts_with("nodes,policy,offered_qps,"));
        // 3 node counts × 2 policies + header.
        assert_eq!(text.lines().count(), 1 + 3 * 2);
        // Goodput is monotone in node count for each policy.
        for policy in ["rr", "jsq"] {
            let goodputs: Vec<f64> = text
                .lines()
                .skip(1)
                .filter(|l| l.split(',').nth(1) == Some(policy))
                .map(|l| l.split(',').nth(5).unwrap().parse().unwrap())
                .collect();
            assert_eq!(goodputs.len(), 3);
            assert!(
                goodputs.windows(2).all(|w| w[1] >= w[0]),
                "{policy} goodput not monotone: {goodputs:?}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
