//! Fig. 13 (SRAM bank size vs effective throughput + DRAM usage) and
//! Table 3 (power & area breakdown).

use super::ExpOptions;
use crate::arch::{area, presets, ArchConfig};
use crate::power::peak_power;
use crate::sim::{memory, simulate, SimOptions};
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::zoo;
use crate::Result;

/// Fig. 13: sweep the SRAM bank size 64 KiB .. 1 MiB on ResNet-152
/// batch 8 (§6.4's workload), reporting normalized effective
/// throughput and DRAM bandwidth usage.
pub fn fig13(opts: &ExpOptions) -> Result<()> {
    // §6.4 uses ResNet-152 at batch 8; quick mode uses batch 4 (same
    // knee, 4× less scheduling work).
    let batch = if opts.quick { 4 } else { 8 };
    let model = zoo::by_name("resnet152").unwrap().with_batch(batch);
    let sizes: Vec<usize> =
        if opts.quick { vec![64, 256, 1024] } else { vec![64, 128, 256, 512, 1024] };
    let mut rows = vec![];
    for &kb in &sizes {
        let cfg =
            ArchConfig { bank_kb: kb, ..presets::by_name("baseline").expect("registered") };
        let stats = simulate(&cfg, &model, &SimOptions::default());
        let mem = memory::analyze(&cfg, std::slice::from_ref(&model));
        rows.push((kb, stats.achieved_ops(&cfg) / 1e12, mem.bandwidth_gbps(&cfg)));
    }
    let best = rows.iter().map(|r| r.1).fold(f64::MIN, f64::max);
    let mut csv = CsvWriter::create(
        format!("{}/fig13.csv", opts.out_dir),
        &["bank_kb", "eff_tops", "normalized", "dram_gbps"],
    )?;
    let mut table = Table::new(&["bank KiB", "eff TOps/s", "norm", "DRAM GB/s"]);
    for (kb, eff, bw) in rows {
        csv.row(&[kb.to_string(), f(eff, 1), f(eff / best, 3), f(bw, 1)])?;
        table.row(vec![kb.to_string(), format!("{eff:.1}"),
                       format!("{:.2}", eff / best), format!("{bw:.0}")]);
    }
    csv.finish()?;
    println!("{table}");
    println!("paper: <256 KiB banks evict tiles → DRAM traffic rises and \
              effective throughput drops; 256 KiB chosen.");
    Ok(())
}

/// Table 3: power and area breakdown of the 256-pod baseline.
pub fn table3(opts: &ExpOptions) -> Result<()> {
    let cfg = presets::by_name("baseline").expect("registered preset");
    let p = peak_power(&cfg);
    let a = area::area(&cfg);
    let rows: Vec<(&str, f64, f64, f64, f64)> = vec![
        // (component, power W, area mm², paper power %, paper area %)
        ("SRAM", p.sram_w, a.sram_mm2, 45.81, 75.37),
        ("Post-processor", p.post_processor_w, a.post_processor_mm2, 0.56, 0.25),
        ("Interconnect", p.interconnect_w, a.interconnect_mm2, 15.06, 4.18),
        ("Systolic arrays", p.mac_w, a.array_mm2, 37.64, 19.76),
        ("Pod control+buffers", p.pod_ctrl_w, a.pod_ctrl_mm2, 0.93, 0.44),
    ];
    let (tp, ta) = (p.total(), a.total());
    let mut csv = CsvWriter::create(
        format!("{}/table3.csv", opts.out_dir),
        &["component", "power_w", "power_pct", "area_mm2", "area_pct",
          "paper_power_pct", "paper_area_pct"],
    )?;
    let mut table = Table::new(&[
        "component", "W", "power %", "mm2", "area %", "paper P%", "paper A%",
    ]);
    for (name, w, mm2, pp, pa) in rows {
        csv.row(&[name.into(), f(w, 2), f(100.0 * w / tp, 2), f(mm2, 1),
                  f(100.0 * mm2 / ta, 2), f(pp, 2), f(pa, 2)])?;
        table.row(vec![
            name.into(), format!("{w:.1}"), format!("{:.1}", 100.0 * w / tp),
            format!("{mm2:.1}"), format!("{:.1}", 100.0 * mm2 / ta),
            format!("{pp}"), format!("{pa}"),
        ]);
    }
    csv.finish()?;
    println!("{table}");
    println!("total: {tp:.1} W, {ta:.0} mm2 (28nm-class constants \
              calibrated to the paper's synthesis shares)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_runs() {
        let dir = std::env::temp_dir().join("sosa_table3");
        let opts = ExpOptions { out_dir: dir.to_str().unwrap().into(), quick: true };
        table3(&opts).unwrap();
        let text = std::fs::read_to_string(dir.join("table3.csv")).unwrap();
        assert!(text.contains("SRAM"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
