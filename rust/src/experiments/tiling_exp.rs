//! Fig. 12b: effective throughput vs activation partition size k
//! (§6.3) — the paper's tiling contribution, plus the no-partition
//! baseline (up to 5× utilization claimed in §8).

use super::ExpOptions;
use crate::arch::ArchConfig;
use crate::sim::{simulate, SimOptions};
use crate::tiling::Strategy;
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::zoo;
use crate::Result;

/// Fig. 12b: sweep the partition size k around r (and include the
/// no-partition baseline), reporting normalized effective throughput.
pub fn fig12b(opts: &ExpOptions) -> Result<()> {
    let cfg = ArchConfig::baseline();
    let r = cfg.array.r;
    let names = if opts.quick {
        vec!["resnet50", "bert-base"]
    } else {
        vec!["resnet50", "resnet152", "densenet121", "bert-medium", "bert-base"]
    };
    let benches: Vec<_> = names.iter().map(|n| zoo::by_name(n).unwrap()).collect();
    let ks: Vec<usize> = if opts.quick {
        vec![8, 32, 128]
    } else {
        vec![4, 8, 16, 32, 64, 128, 256, 512]
    };

    let mut csv = CsvWriter::create(
        format!("{}/fig12b.csv", opts.out_dir),
        &["k", "eff_tops", "normalized"],
    )?;
    let mut results: Vec<(String, f64)> = vec![];
    for &k in &ks {
        let opts_k = SimOptions { strategy: Strategy::Fixed(k), ..Default::default() };
        let mut eff = 0.0;
        for m in &benches {
            eff += simulate(&cfg, m, &opts_k).achieved_ops(&cfg);
        }
        results.push((k.to_string(), eff / benches.len() as f64 / 1e12));
    }
    // No-partition baseline (AI-MT-style).
    {
        let opts_np = SimOptions { strategy: Strategy::NoPartition, ..Default::default() };
        let mut eff = 0.0;
        for m in &benches {
            eff += simulate(&cfg, m, &opts_np).achieved_ops(&cfg);
        }
        results.push(("none".into(), eff / benches.len() as f64 / 1e12));
    }
    let best = results.iter().map(|r| r.1).fold(f64::MIN, f64::max);
    let mut table = Table::new(&["partition k", "eff TOps/s", "normalized"]);
    for (k, eff) in &results {
        csv.row(&[k.clone(), f(*eff, 1), f(eff / best, 3)])?;
        table.row(vec![k.clone(), format!("{eff:.1}"), format!("{:.2}", eff / best)]);
    }
    csv.finish()?;
    println!("{table}");
    let at_r = results.iter().find(|(k, _)| k == &r.to_string()).unwrap().1;
    let none = results.last().unwrap().1;
    println!("optimum at k = r = {r} (paper Fig. 12b); r-vs-no-partition \
              gain: {:.2}x (paper: up to 5x utilization)", at_r / none);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, ArrayDims};

    #[test]
    fn k_equal_r_beats_extremes() {
        // The Fig. 12b shape on one benchmark: k = r ≥ both k ≪ r and
        // no partitioning.
        let cfg = ArchConfig::with_array(ArrayDims::new(32, 32), 256);
        let m = zoo::by_name("resnet50").unwrap();
        let eff = |strategy| {
            let o = SimOptions { strategy, ..Default::default() };
            simulate(&cfg, &m, &o).achieved_ops(&cfg)
        };
        let at_r = eff(Strategy::Fixed(32));
        let tiny = eff(Strategy::Fixed(4));
        let none = eff(Strategy::NoPartition);
        assert!(at_r > tiny, "k=r {at_r} vs k=4 {tiny}");
        assert!(at_r > none, "k=r {at_r} vs none {none}");
    }
}
