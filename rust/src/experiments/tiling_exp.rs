//! Fig. 12b: effective throughput vs activation partition size k
//! (§6.3) — the paper's tiling contribution, plus the no-partition
//! baseline (up to 5× utilization claimed in §8) — and the `perlayer`
//! experiment: per-layer strategy selection (analytic and exhaustive)
//! against the best global strategies, the paper-beyond step the
//! compile pipeline enables.

use super::ExpOptions;
use crate::arch::{presets, ArchConfig, ArrayDims};
use crate::compile::{SelectOptions, TilingSpec};
use crate::explore::{DesignSpace, Explorer};
use crate::sim::{simulate_with, SimContext, SimOptions};
use crate::tiling::Strategy;
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::zoo;
use crate::Result;

/// Fig. 12b's partition-size axis.
pub fn fig12b_ks(quick: bool) -> Vec<usize> {
    if quick {
        vec![8, 32, 128]
    } else {
        vec![4, 8, 16, 32, 64, 128, 256, 512]
    }
}

/// The Fig. 12b design space — the exact (tiling × benchmark) grid
/// `fig12b` sweeps on the baseline preset: every `Fixed(k)` then the
/// no-partition baseline, spec-major.  Public for the two-tier
/// certification tests.
pub fn fig12b_space(quick: bool) -> DesignSpace {
    let cfg = presets::by_name("baseline").expect("registered preset");
    let names = if quick {
        vec!["resnet50", "bert-base"]
    } else {
        vec!["resnet50", "resnet152", "densenet121", "bert-medium", "bert-base"]
    };
    let benches: Vec<_> = names.iter().map(|n| zoo::by_name(n).unwrap()).collect();
    let mut specs: Vec<TilingSpec> = fig12b_ks(quick)
        .iter()
        .map(|&k| TilingSpec::Global(Strategy::Fixed(k)))
        .collect();
    specs.push(TilingSpec::Global(Strategy::NoPartition));
    DesignSpace::new(cfg).tiling(&specs).workloads(benches)
}

/// Fig. 12b: sweep the partition size k around r (and include the
/// no-partition baseline), reporting normalized effective throughput.
/// Declared as a [`DesignSpace`] over the tiling axis (the third
/// pillar); output byte-identical to the pre-`explore` loop.
pub fn fig12b(opts: &ExpOptions) -> Result<()> {
    let cfg = presets::by_name("baseline").expect("registered preset");
    let r = cfg.array.r;
    let n_bench = if opts.quick { 2 } else { 5 };
    let ks = fig12b_ks(opts.quick);

    let mut csv = CsvWriter::create(
        format!("{}/fig12b.csv", opts.out_dir),
        &["k", "eff_tops", "normalized"],
    )?;
    // Tiling axis: every Fixed(k), then the no-partition baseline
    // (AI-MT-style); records are spec-major in that order.
    let labels: Vec<String> = ks
        .iter()
        .map(|k| k.to_string())
        .chain(std::iter::once("none".into()))
        .collect();
    let x = Explorer::new().evaluate(&fig12b_space(opts.quick))?;
    let results: Vec<(String, f64)> = labels
        .into_iter()
        .enumerate()
        .map(|(ti, label)| {
            let eff = x.records[ti * n_bench..(ti + 1) * n_bench]
                .iter()
                .map(|rec| rec.stats.achieved_ops(&cfg))
                .sum::<f64>()
                / n_bench as f64
                / 1e12;
            (label, eff)
        })
        .collect();
    let best = results.iter().map(|r| r.1).fold(f64::MIN, f64::max);
    let mut table = Table::new(&["partition k", "eff TOps/s", "normalized"]);
    for (k, eff) in &results {
        csv.row(&[k.clone(), f(*eff, 1), f(eff / best, 3)])?;
        table.row(vec![k.clone(), format!("{eff:.1}"), format!("{:.2}", eff / best)]);
    }
    csv.finish()?;
    println!("{table}");
    let at_r = results.iter().find(|(k, _)| k == &r.to_string()).unwrap().1;
    let none = results.last().unwrap().1;
    println!("optimum at k = r = {r} (paper Fig. 12b); r-vs-no-partition \
              gain: {:.2}x (paper: up to 5x utilization)", at_r / none);
    Ok(())
}

/// The `perlayer` experiment (fig12b taken per layer): for each
/// workload, effective throughput under global r×r / the best global
/// Fixed(k) / no partition, versus per-layer selection — analytic
/// ([`TilingSpec::Auto`]) and exhaustive per-layer search.  The
/// per-layer columns are never worse than global r×r by construction
/// (scheduler-verified arbitration); the interesting signal is where
/// they *beat* every global point.
pub fn perlayer(opts: &ExpOptions) -> Result<()> {
    // 64 pods: saturated enough that per-layer partition choices move
    // wave counts (at 256 pods most benchmarks never fill the machine
    // and selection correctly ties back to r×r).
    let cfg = ArchConfig::with_array(ArrayDims::new(32, 32), 64);
    let models: Vec<crate::workloads::ModelGraph> = if opts.quick {
        // Small but r-unaligned shapes (50-token ViT) keep the
        // exhaustive column cheap for smoke runs.
        vec![
            zoo::by_name("bert-medium").unwrap(),
            crate::workloads::extra::vit_base(32, 224),
        ]
    } else {
        vec![
            zoo::by_name("resnet50").unwrap(),
            zoo::by_name("bert-medium").unwrap(),
            zoo::by_name("bert-base").unwrap(),
            zoo::by_name("vit-base").unwrap(),
            zoo::by_name("mobilenet").unwrap(),
        ]
    };
    let ks: Vec<usize> = if opts.quick { vec![8, 64] } else { vec![8, 16, 64, 128] };

    let mut csv = CsvWriter::create(
        format!("{}/perlayer.csv", opts.out_dir),
        &["model", "rxr_tops", "best_fixed_k", "best_fixed_tops", "nopart_tops",
          "auto_tops", "exhaustive_tops", "auto_layers_changed", "perlayer_gain"],
    )?;
    let mut table = Table::new(&[
        "model", "r×r", "best Fixed(k)", "none", "auto", "exhaustive", "Δlayers", "gain",
    ]);
    let mut ctx = SimContext::new();
    for m in &models {
        let eff = |spec: TilingSpec, ctx: &mut SimContext| {
            let o = SimOptions { spec, memory_model: false, ..Default::default() };
            simulate_with(ctx, &cfg, m, &o).achieved_ops(&cfg) / 1e12
        };
        let rxr = eff(TilingSpec::Global(Strategy::RxR), &mut ctx);
        let nopart = eff(TilingSpec::Global(Strategy::NoPartition), &mut ctx);
        let (best_k, best_fixed) = ks
            .iter()
            .map(|&k| (k, eff(TilingSpec::Global(Strategy::Fixed(k)), &mut ctx)))
            .fold((cfg.array.r, rxr), |acc, cur| if cur.1 > acc.1 { cur } else { acc });
        // Compile the Auto plan once: it yields both the throughput
        // (execute the artifact) and the layers-changed diagnostic.
        let auto_opts = SimOptions {
            spec: TilingSpec::auto(),
            memory_model: false,
            ..Default::default()
        };
        let cp = crate::compile::compile_with(&mut ctx, &cfg, m, &auto_opts);
        let changed = cp.non_rxr_layers();
        let auto = cp.execute_with(&mut ctx, &cfg, &auto_opts).achieved_ops(&cfg) / 1e12;
        let exhaustive = eff(TilingSpec::Auto(SelectOptions::exhaustive()), &mut ctx);
        // Best per-layer result (either mode) over the global default.
        let gain = if rxr > 0.0 { auto.max(exhaustive) / rxr } else { 1.0 };

        csv.row(&[
            m.name.clone(),
            f(rxr, 2),
            best_k.to_string(),
            f(best_fixed, 2),
            f(nopart, 2),
            f(auto, 2),
            f(exhaustive, 2),
            changed.to_string(),
            f(gain, 3),
        ])?;
        table.row(vec![
            m.name.clone(),
            format!("{rxr:.2}"),
            format!("{best_fixed:.2} (k={best_k})"),
            format!("{nopart:.2}"),
            format!("{auto:.2}"),
            format!("{exhaustive:.2}"),
            changed.to_string(),
            format!("{gain:.3}x"),
        ]);
    }
    csv.finish()?;
    println!("{table}");
    println!("per-layer selection is scheduler-verified: the auto/exhaustive \
              columns are >= the r×r column by construction, and beat the best \
              global point where layer shapes are r-unaligned (e.g. ViT's 197 \
              tokens).");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::simulate;

    #[test]
    fn k_equal_r_beats_extremes() {
        // The Fig. 12b shape on one benchmark: k = r ≥ both k ≪ r and
        // no partitioning.
        let cfg = ArchConfig::with_array(ArrayDims::new(32, 32), 256);
        let m = zoo::by_name("resnet50").unwrap();
        let eff = |strategy| {
            let o = SimOptions { spec: TilingSpec::Global(strategy), ..Default::default() };
            simulate(&cfg, &m, &o).achieved_ops(&cfg)
        };
        let at_r = eff(Strategy::Fixed(32));
        let tiny = eff(Strategy::Fixed(4));
        let none = eff(Strategy::NoPartition);
        assert!(at_r > tiny, "k=r {at_r} vs k=4 {tiny}");
        assert!(at_r > none, "k=r {at_r} vs none {none}");
    }

    #[test]
    fn perlayer_experiment_runs_quick() {
        let dir = std::env::temp_dir().join("sosa_perlayer_exp");
        std::fs::create_dir_all(&dir).unwrap();
        let opts = ExpOptions { out_dir: dir.to_str().unwrap().into(), quick: true };
        perlayer(&opts).unwrap();
        assert!(dir.join("perlayer.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
