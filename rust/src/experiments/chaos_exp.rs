//! The `chaos` experiment: goodput retained under one node loss at
//! peak load — the fleet-dynamics pinned scenario.
//!
//! A 4-node JSQ fleet serves the quick workload mix at ~90% of its
//! estimated capacity (peak), once healthy and once with one node dark
//! for the middle third of the run (plus a straggler scenario where a
//! node keeps serving at half clock).  The interesting number is the
//! `retained` column: goodput under chaos as a fraction of healthy
//! goodput.  With 1 of 4 nodes lost for 1/3 of the run the linear
//! bound on lost capacity is 1/12 ≈ 8%, so retained should stay well
//! above the naive 3/4 floor — health-aware routing spreads the
//! surviving load instead of black-holing it.  Output: `chaos.csv`,
//! pinned byte-for-byte by `tests/golden.rs` (`chaos_quick.csv`).

use super::ExpOptions;
use crate::arch::{ArchConfig, ArrayDims};
use crate::cluster::{
    analyze_fleet, ChaosSchedule, CrashWindow, Fleet, FleetConfig, Policy,
};
use crate::serve::{default_deadline, generate, BatchPolicy, EngineConfig, Tenant, TrafficSpec};
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::{bert::bert_named, zoo};
use crate::Result;

/// Same workload-mix rule as the `fleet` experiment: §5 pairing in
/// full mode, the Fig. 5 BERT stand-ins in quick mode.
fn mix(quick: bool) -> Vec<Tenant> {
    if quick {
        vec![
            Tenant::new(bert_named("mini", 100), 1.0),
            Tenant::new(bert_named("small", 100), 1.0),
        ]
    } else {
        vec![
            Tenant::new(zoo::by_name("resnet50").expect("zoo model"), 1.0),
            Tenant::new(zoo::by_name("bert-base").expect("zoo model"), 1.0),
        ]
    }
}

/// Per-node architecture (quick shrinks the node, not the logic).
fn node_config(quick: bool) -> ArchConfig {
    if quick {
        ArchConfig::with_array(ArrayDims::new(16, 16), 16)
    } else {
        ArchConfig::with_array(ArrayDims::new(32, 32), 64)
    }
}

/// The scenarios' shared fleet: 4 homogeneous nodes behind JSQ.
fn fleet_for(quick: bool) -> Result<Fleet> {
    Fleet::homogeneous(
        4,
        node_config(quick),
        FleetConfig {
            policy: Policy::JoinShortestQueue,
            engine: EngineConfig {
                policy: BatchPolicy {
                    max_batch: if quick { 4 } else { 8 },
                    max_wait_s: 2e-3,
                },
                ..Default::default()
            },
            ..Default::default()
        },
    )
}

/// Run the node-loss resilience experiment.
pub fn chaos(opts: &ExpOptions) -> Result<()> {
    let duration_s = if opts.quick { 0.05 } else { 0.5 };
    let seed = 42u64;
    let tenants = mix(opts.quick);
    let fleet = fleet_for(opts.quick)?;
    let n = fleet.len();

    // Peak load: 90% of the healthy fleet's estimated capacity, fixed
    // across scenarios so goodput differences come from the injected
    // faults, not from traffic.
    let node_cap = fleet.capacity_qps(&tenants) / n as f64;
    let offered = 0.9 * node_cap * n as f64;
    let max_batch = if opts.quick { 4 } else { 8 };
    let deadline_s = default_deadline(max_batch, node_cap);
    let arrivals = generate(&TrafficSpec::poisson(offered, duration_s, seed), &tenants);

    // One node dark for the middle third of the run; separately, one
    // node serving at half clock for the whole run.
    let one_down = ChaosSchedule {
        crashes: vec![CrashWindow {
            node: 1,
            down_t: duration_s / 3.0,
            up_t: 2.0 * duration_s / 3.0,
        }],
        ..Default::default()
    };
    let straggler =
        ChaosSchedule { stragglers: vec![(2, 2.0)], ..Default::default() };
    let healthy = ChaosSchedule::default();
    let scenarios: &[(&str, &ChaosSchedule)] =
        &[("healthy", &healthy), ("one_down", &one_down), ("straggler", &straggler)];

    let mut csv = CsvWriter::create(
        format!("{}/chaos.csv", opts.out_dir),
        &["scenario", "offered_qps", "p50_ms", "p99_ms", "goodput_qps", "completed",
          "rejected", "unroutable", "redispatched", "retained"],
    )?;
    let mut table = Table::new(&[
        "scenario", "offered", "p50 ms", "p99 ms", "goodput", "unroutable",
        "redisp", "retained",
    ]);
    let mut healthy_goodput = 0.0f64;
    for (i, (name, sched)) in scenarios.iter().enumerate() {
        let rep = fleet.serve_chaos(&tenants, &arrivals, sched, None, None)?;
        let slo = analyze_fleet(&fleet, &rep, duration_s, deadline_s);
        if i == 0 {
            healthy_goodput = slo.slo.goodput_qps;
        }
        let retained = if healthy_goodput > 0.0 {
            slo.slo.goodput_qps / healthy_goodput
        } else {
            0.0
        };
        csv.row(&[
            name.to_string(),
            f(offered, 1),
            f(slo.slo.latency.p50 * 1e3, 3),
            f(slo.slo.latency.p99 * 1e3, 3),
            f(slo.slo.goodput_qps, 1),
            slo.slo.completed.to_string(),
            slo.slo.rejected.to_string(),
            slo.unroutable.to_string(),
            slo.redispatched.to_string(),
            f(retained, 3),
        ])?;
        table.row(vec![
            name.to_string(),
            format!("{offered:.0}"),
            format!("{:.3}", slo.slo.latency.p50 * 1e3),
            format!("{:.3}", slo.slo.latency.p99 * 1e3),
            format!("{:.1}", slo.slo.goodput_qps),
            slo.unroutable.to_string(),
            slo.redispatched.to_string(),
            format!("{retained:.3}"),
        ]);
    }
    csv.finish()?;
    println!("{table}");
    println!(
        "offered {offered:.0} req/s fixed across scenarios (0.9x the {n}-node \
         fleet's estimated capacity); `retained` is goodput vs the healthy row"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_chaos_experiment_retains_goodput_under_node_loss() {
        let dir = std::env::temp_dir().join("sosa_chaos_exp");
        std::fs::remove_dir_all(&dir).ok();
        let opts = ExpOptions { out_dir: dir.to_str().unwrap().into(), quick: true };
        chaos(&opts).unwrap();
        let text = std::fs::read_to_string(dir.join("chaos.csv")).unwrap();
        assert!(text.starts_with("scenario,offered_qps,"));
        assert_eq!(text.lines().count(), 1 + 3, "header + 3 scenarios");
        let retained: Vec<(String, f64)> = text
            .lines()
            .skip(1)
            .map(|l| {
                let cells: Vec<&str> = l.split(',').collect();
                (cells[0].to_string(), cells[9].parse().unwrap())
            })
            .collect();
        assert_eq!(retained[0].0, "healthy");
        assert_eq!(retained[0].1, 1.0, "healthy row is its own baseline");
        let one_down = retained.iter().find(|(s, _)| s == "one_down").unwrap().1;
        // 1 of 4 nodes gone for 1/3 of the run caps the *linear* loss
        // at 1/12; allow generous queueing slack but require the
        // routing layer to keep well over the naive 3/4 floor.
        assert!(
            one_down > 0.75 && one_down <= 1.0,
            "one-node-loss retained goodput {one_down} outside (0.75, 1.0]"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
