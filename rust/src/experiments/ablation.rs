//! Ablations of the design choices DESIGN.md calls out (extensions
//! beyond the paper's own evaluation):
//!
//! * scheduler dedicated vs shared single-ported banks (§4.2 readings),
//! * bounded pod search width (`max_pod_tries`),
//! * U/V multicast/fan-in degrees (§4.1's pipeline-latency knob).

use super::ExpOptions;
use crate::arch::{presets, ArrayDims};
use crate::sim::pod::PodTiming;
use crate::sim::{simulate_with, SimContext, SimOptions};
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::zoo;
use crate::Result;

/// Run the ablation suite.
pub fn ablation(opts: &ExpOptions) -> Result<()> {
    let cfg = presets::by_name("baseline").expect("registered preset");
    let model = zoo::by_name(if opts.quick { "densenet121" } else { "resnet50" }).unwrap();

    let mut csv = CsvWriter::create(
        format!("{}/ablation.csv", opts.out_dir),
        &["knob", "value", "utilization", "metric"],
    )?;
    let mut table = Table::new(&["knob", "value", "util %", "notes"]);

    // One pooled context across the whole suite: every run shares the
    // same (interconnect, pods, window) key, so checkouts are free.
    let mut ctx = SimContext::new();

    // (a) Bank organization.
    for (label, shared) in [("dedicated", false), ("shared-pool", true)] {
        let mut o = SimOptions::default();
        o.sched.shared_banks = shared;
        let s = simulate_with(&mut ctx, &cfg, &model, &o);
        let u = s.utilization(&cfg);
        csv.row(&["banks".into(), label.into(), f(u, 4), f(0.0, 1)])?;
        table.row(vec!["banks".into(), label.into(), format!("{:.1}", u * 100.0),
                       "§4.2 strictest reading costs utilization".into()]);
    }

    // (b) Pod search width.
    for tries in [1usize, 2, 4, 8, 16] {
        let mut o = SimOptions::default();
        o.sched.max_pod_tries = tries;
        let s = simulate_with(&mut ctx, &cfg, &model, &o);
        let u = s.utilization(&cfg);
        csv.row(&["pod_tries".into(), tries.to_string(), f(u, 4),
                  s.deferred_slices.to_string()])?;
        table.row(vec!["pod_tries".into(), tries.to_string(),
                       format!("{:.1}", u * 100.0),
                       format!("{} deferred slices", s.deferred_slices)]);
    }

    // (c) U/V pipeline degrees (analytic pod model, §4.1).
    for uv in [1usize, 2, 4, 8, 16, 32] {
        let t = PodTiming::new(ArrayDims::new(32, 32), uv, uv);
        let score = t.utilization(32) / t.clock_period_factor();
        csv.row(&["uv".into(), uv.to_string(), f(t.utilization(32), 4), f(score, 4)])?;
        table.row(vec!["U=V".into(), uv.to_string(),
                       format!("{:.1}", t.utilization(32) * 100.0),
                       format!("freq-adjusted score {score:.3}")]);
    }

    csv.finish()?;
    println!("{table}");
    println!("paper picks U=V=16 for 32x32 (§4.1) — the freq-adjusted \
              score peaks there; dedicated banks and tries ≥ 4 match the \
              §4.2 scheduler's assumptions.");
    Ok(())
}
