//! Table 2 (array granularity @400 W) and Fig. 9 (per-benchmark
//! effective throughput by array size).

use super::ExpOptions;
use crate::arch::{ArchConfig, ArrayDims};
use crate::power::{max_pods_under_tdp, peak_power, throughput_at_tdp, TDP_W};
use crate::sim::{simulate_with, SimOptions, SweepExecutor};
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::zoo;
use crate::Result;

/// The granularities of Table 2 (with paper reference values).
pub const SIZES: &[(usize, f64, f64)] = &[
    // (dim, paper utilization %, paper effective TOps/s @400 W)
    (512, 10.3, 191.3),
    (256, 14.0, 183.0),
    (128, 13.8, 205.0),
    (64, 17.4, 200.9),
    (32, 39.4, 317.4),
    (16, 40.0, 198.9),
];

fn config_for(dim: usize) -> ArchConfig {
    // 512×512 is the *monolithic* baseline (Table 2 row 1): one array
    // by definition, even though two would fit the 400 W envelope.
    let pods = if dim >= 512 {
        1
    } else {
        let template = ArchConfig::with_array(ArrayDims::new(dim, dim), 1);
        max_pods_under_tdp(&template, TDP_W).max(1)
    };
    ArchConfig::with_array(ArrayDims::new(dim, dim), pods)
}

/// Table 2: pods / peak power / peak@400W / util / effective@400W per
/// array granularity, averaged over the ten benchmarks.
pub fn table2(opts: &ExpOptions) -> Result<()> {
    let benches = zoo::benchmarks();
    let sim_opts = SimOptions::default();
    let mut csv = CsvWriter::create(
        format!("{}/table2.csv", opts.out_dir),
        &["array", "pods", "peak_w", "peak_tops_at_400w", "util", "eff_tops",
          "paper_util", "paper_eff_tops"],
    )?;
    let mut table = Table::new(&[
        "array", "pods", "peakW", "peak@400W", "util%", "eff TOps/s",
        "paper util%", "paper eff",
    ]);
    let sizes: Vec<_> = if opts.quick {
        SIZES.iter().filter(|s| s.0 >= 32).cloned().collect()
    } else {
        SIZES.to_vec()
    };
    // Fan the (granularity × benchmark) grid across cores — one pooled
    // context per worker; rows are assembled in sweep order below.
    let cfgs: Vec<ArchConfig> = sizes.iter().map(|&(dim, _, _)| config_for(dim)).collect();
    let grid: Vec<(usize, usize)> = (0..sizes.len())
        .flat_map(|si| (0..benches.len()).map(move |bi| (si, bi)))
        .collect();
    let utils: Vec<f64> = SweepExecutor::new().run_with_ctx(&grid, |ctx, _, &(si, bi)| {
        simulate_with(ctx, &cfgs[si], &benches[bi], &sim_opts).utilization(&cfgs[si])
    });
    for (si, &(dim, paper_util, paper_eff)) in sizes.iter().enumerate() {
        let cfg = &cfgs[si];
        let per_bench = &utils[si * benches.len()..(si + 1) * benches.len()];
        let util = per_bench.iter().sum::<f64>() / benches.len() as f64;
        let tp = throughput_at_tdp(cfg, TDP_W);
        let eff = util * tp.peak_ops_at_tdp / 1e12;
        csv.row(&[
            format!("{dim}x{dim}"),
            cfg.num_pods.to_string(),
            f(tp.peak_power_w, 1),
            f(tp.peak_ops_at_tdp / 1e12, 0),
            f(util * 100.0, 1),
            f(eff, 1),
            f(paper_util, 1),
            f(paper_eff, 1),
        ])?;
        table.row(vec![
            format!("{dim}x{dim}"),
            cfg.num_pods.to_string(),
            format!("{:.1}", peak_power(cfg).total()),
            format!("{:.0}", tp.peak_ops_at_tdp / 1e12),
            format!("{:.1}", util * 100.0),
            format!("{eff:.1}"),
            format!("{paper_util}"),
            format!("{paper_eff}"),
        ]);
    }
    csv.finish()?;
    println!("{table}");
    Ok(())
}

/// Fig. 9: effective throughput per benchmark per array size.
pub fn fig9(opts: &ExpOptions) -> Result<()> {
    let benches = zoo::benchmarks();
    let sim_opts = SimOptions::default();
    let dims: Vec<usize> =
        if opts.quick { vec![32, 128] } else { vec![16, 32, 64, 128, 256, 512] };
    let mut csv = CsvWriter::create(
        format!("{}/fig9.csv", opts.out_dir),
        &["model", "array", "util", "eff_tops"],
    )?;
    let mut table = Table::new(
        &std::iter::once("model")
            .chain(dims.iter().map(|d| match d {
                16 => "16x16", 32 => "32x32", 64 => "64x64", 128 => "128x128",
                256 => "256x256", _ => "512x512",
            }))
            .collect::<Vec<_>>(),
    );
    // Fan the (granularity × benchmark) grid across cores,
    // config-major so consecutive items share a context key (each dim
    // has its own pod count; benchmark-major would rebuild the pooled
    // fabric ring on every item).  The serial loop below reads the
    // cells back in deterministic order.
    let cfgs: Vec<ArchConfig> = dims.iter().map(|&d| config_for(d)).collect();
    let grid: Vec<(usize, usize)> = (0..dims.len())
        .flat_map(|di| (0..benches.len()).map(move |mi| (mi, di)))
        .collect();
    let cells: Vec<(f64, f64)> = SweepExecutor::new().run_with_ctx(&grid, |ctx, _, &(mi, di)| {
        let cfg = &cfgs[di];
        let s = simulate_with(ctx, cfg, &benches[mi], &sim_opts);
        (s.utilization(cfg), s.effective_ops_at_tdp(cfg, TDP_W) / 1e12)
    });
    let mut wins32 = 0usize;
    for (mi, m) in benches.iter().enumerate() {
        let mut row = vec![m.name.clone()];
        let mut best = (0usize, f64::MIN);
        for (di, &dim) in dims.iter().enumerate() {
            let (util, eff) = cells[di * benches.len() + mi];
            csv.row(&[m.name.clone(), format!("{dim}x{dim}"),
                      f(util, 4), f(eff, 1)])?;
            row.push(format!("{eff:.0}"));
            if eff > best.1 {
                best = (dim, eff);
            }
        }
        if best.0 == 32 {
            wins32 += 1;
        }
        table.row(row);
    }
    csv.finish()?;
    println!("{table}");
    println!("32x32 wins {wins32}/{} benchmarks (paper: 9/10, BERT-large \
              the exception)", benches.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_for_matches_table2_pods() {
        assert_eq!(config_for(32).num_pods, 256);
        assert_eq!(config_for(128).num_pods, 32);
        assert_eq!(config_for(512).num_pods, 1, "monolithic baseline");
    }
}
