//! Table 2 (array granularity @400 W) and Fig. 9 (per-benchmark
//! effective throughput by array size), declared as
//! [`DesignSpace`] sweeps: the granularity axis zipped with its §6 pod
//! provisioning, crossed with the ten benchmarks, evaluated through
//! the explore pipeline (pooled contexts, parallel executor).  The
//! CSV/stdout outputs are byte-identical to the pre-`explore`
//! hand-rolled loops (pinned by `tests/golden.rs`).

use super::ExpOptions;
use crate::arch::{ArchConfig, ArrayDims};
use crate::explore::{DesignSpace, Explorer};
use crate::power::{max_pods_under_tdp, peak_power, throughput_at_tdp, TDP_W};
use crate::util::{csv::f, CsvWriter, Table};
use crate::workloads::zoo;
use crate::Result;

/// The granularities of Table 2 (with paper reference values).
pub const SIZES: &[(usize, f64, f64)] = &[
    // (dim, paper utilization %, paper effective TOps/s @400 W)
    (512, 10.3, 191.3),
    (256, 14.0, 183.0),
    (128, 13.8, 205.0),
    (64, 17.4, 200.9),
    (32, 39.4, 317.4),
    (16, 40.0, 198.9),
];

pub(crate) fn config_for(dim: usize) -> ArchConfig {
    // 512×512 is the *monolithic* baseline (Table 2 row 1): one array
    // by definition, even though two would fit the 400 W envelope.
    let pods = if dim >= 512 {
        1
    } else {
        let template = ArchConfig::with_array(ArrayDims::new(dim, dim), 1);
        max_pods_under_tdp(&template, TDP_W).max(1)
    };
    ArchConfig::with_array(ArrayDims::new(dim, dim), pods)
}

/// The Table 2 / Fig. 9 design space: square arrays at the paper's
/// granularities, each zipped with its §6 pod count (monolithic rule
/// included), crossed with the ten benchmarks.  Public so the two-tier
/// certification tests and `benches/explore.rs` A/B the *exact* grids
/// the experiments run.
pub fn granularity_space(
    dims: &[usize],
    benches: Vec<crate::workloads::ModelGraph>,
) -> DesignSpace {
    let pods: Vec<usize> = dims.iter().map(|&d| config_for(d).num_pods).collect();
    DesignSpace::baseline()
        .square_arrays(dims)
        .pods_zip(&pods)
        .workloads(benches)
}

/// Table 2's granularity axis (quick drops the slow sub-32 rows) —
/// the dims `table2` itself sweeps.
pub fn table2_dims(quick: bool) -> Vec<usize> {
    SIZES.iter().filter(|s| !quick || s.0 >= 32).map(|s| s.0).collect()
}

/// Fig. 9's granularity axis.
pub fn fig9_dims(quick: bool) -> Vec<usize> {
    if quick {
        vec![32, 128]
    } else {
        vec![16, 32, 64, 128, 256, 512]
    }
}

/// Table 2: pods / peak power / peak@400W / util / effective@400W per
/// array granularity, averaged over the ten benchmarks.
pub fn table2(opts: &ExpOptions) -> Result<()> {
    let mut csv = CsvWriter::create(
        format!("{}/table2.csv", opts.out_dir),
        &["array", "pods", "peak_w", "peak_tops_at_400w", "util", "eff_tops",
          "paper_util", "paper_eff_tops"],
    )?;
    let mut table = Table::new(&[
        "array", "pods", "peakW", "peak@400W", "util%", "eff TOps/s",
        "paper util%", "paper eff",
    ]);
    let sizes: Vec<_> = if opts.quick {
        SIZES.iter().filter(|s| s.0 >= 32).cloned().collect()
    } else {
        SIZES.to_vec()
    };
    // Declare the (granularity × benchmark) grid and evaluate it on
    // the explore pipeline; records are in enumeration order (size
    // outer, benchmark inner), so each size's rows slice out directly.
    let dims = table2_dims(opts.quick);
    let benches = zoo::benchmarks();
    let n_bench = benches.len();
    let x = Explorer::new().evaluate(&granularity_space(&dims, benches))?;
    for (si, &(dim, paper_util, paper_eff)) in sizes.iter().enumerate() {
        let recs = &x.records[si * n_bench..(si + 1) * n_bench];
        let cfg = &recs[0].point.cfg;
        let util = recs.iter().map(|r| r.utilization).sum::<f64>() / n_bench as f64;
        let tp = throughput_at_tdp(cfg, TDP_W);
        let eff = util * tp.peak_ops_at_tdp / 1e12;
        csv.row(&[
            format!("{dim}x{dim}"),
            cfg.num_pods.to_string(),
            f(tp.peak_power_w, 1),
            f(tp.peak_ops_at_tdp / 1e12, 0),
            f(util * 100.0, 1),
            f(eff, 1),
            f(paper_util, 1),
            f(paper_eff, 1),
        ])?;
        table.row(vec![
            format!("{dim}x{dim}"),
            cfg.num_pods.to_string(),
            format!("{:.1}", peak_power(cfg).total()),
            format!("{:.0}", tp.peak_ops_at_tdp / 1e12),
            format!("{:.1}", util * 100.0),
            format!("{eff:.1}"),
            format!("{paper_util}"),
            format!("{paper_eff}"),
        ]);
    }
    csv.finish()?;
    println!("{table}");
    Ok(())
}

/// Fig. 9: effective throughput per benchmark per array size.
pub fn fig9(opts: &ExpOptions) -> Result<()> {
    let dims = fig9_dims(opts.quick);
    let mut csv = CsvWriter::create(
        format!("{}/fig9.csv", opts.out_dir),
        &["model", "array", "util", "eff_tops"],
    )?;
    let mut table = Table::new(
        &std::iter::once("model")
            .chain(dims.iter().map(|d| match d {
                16 => "16x16", 32 => "32x32", 64 => "64x64", 128 => "128x128",
                256 => "256x256", _ => "512x512",
            }))
            .collect::<Vec<_>>(),
    );
    // Same declarative space as Table 2 — records are size-major
    // (consecutive points share a pooled-context key), read back
    // benchmark-major below for the paper's per-model rows.
    let benches = zoo::benchmarks();
    let names: Vec<String> = benches.iter().map(|m| m.name.clone()).collect();
    let n_bench = benches.len();
    let x = Explorer::new().evaluate(&granularity_space(&dims, benches))?;
    let mut wins32 = 0usize;
    for (mi, name) in names.iter().enumerate() {
        let mut row = vec![name.clone()];
        let mut best = (0usize, f64::MIN);
        for (di, &dim) in dims.iter().enumerate() {
            let rec = &x.records[di * n_bench + mi];
            let (util, eff) = (rec.utilization, rec.eff_tops);
            csv.row(&[name.clone(), format!("{dim}x{dim}"),
                      f(util, 4), f(eff, 1)])?;
            row.push(format!("{eff:.0}"));
            if eff > best.1 {
                best = (dim, eff);
            }
        }
        if best.0 == 32 {
            wins32 += 1;
        }
        table.row(row);
    }
    csv.finish()?;
    println!("{table}");
    println!("32x32 wins {wins32}/{} benchmarks (paper: 9/10, BERT-large \
              the exception)", n_bench);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_for_matches_table2_pods() {
        assert_eq!(config_for(32).num_pods, 256);
        assert_eq!(config_for(128).num_pods, 32);
        assert_eq!(config_for(512).num_pods, 1, "monolithic baseline");
    }

    #[test]
    fn granularity_space_reproduces_config_for() {
        let benches = zoo::benchmarks();
        let n = benches.len();
        let e = granularity_space(&[32, 512], benches).enumerate().unwrap();
        assert_eq!(e.points.len(), 2 * n);
        assert_eq!(e.points[0].cfg, config_for(32));
        assert_eq!(e.points[n].cfg, config_for(512));
    }
}
