//! The `serve` subcommand of `sosa-experiments`: trace-driven online
//! serving over the model zoo with SLO reporting and load sweeps.
//!
//! ```bash
//! sosa-experiments serve --model bert-large --qps 2000 --seed 7
//! sosa-experiments serve --models resnet50,bert-medium --partitioned \
//!                        --qps 800 --duration 2
//! sosa-experiments serve --model bert-large --sweep --out results
//! ```
//!
//! Everything printed to stdout is a pure function of the arguments:
//! two runs with the same flags produce byte-identical reports (timing
//! diagnostics go to stderr).  Sweeps fan their points across cores
//! (`--threads N` / `SOSA_THREADS` to pin; the thread count never
//! changes the output, only the wall clock).

use super::ExpOptions;
use crate::arch::{ArchConfig, ArrayDims};
use crate::compile::TilingSpec;
use crate::error::{Error, Result};
use crate::serve::{
    analyze, capacity_qps, default_deadline, generate, load_sweep, max_sustainable_qps,
    serve_partitioned_threads, serve_shared, sweep_table, write_sweep_csv, Admission,
    BatchPolicy, EngineConfig, SweepOptions, Tenant, TrafficSpec, SWEEP_LADDER,
};
use crate::util::cli::Args;
use crate::workloads::zoo;

fn parse_array(s: &str) -> Result<ArrayDims> {
    let (r, c) = s
        .split_once('x')
        .ok_or_else(|| Error::config(format!("array must be RxC, got {s}")))?;
    let r = r.parse().map_err(|_| Error::config("bad array rows"))?;
    let c = c.parse().map_err(|_| Error::config("bad array cols"))?;
    Ok(ArrayDims::new(r, c))
}

fn tenants_from(args: &Args) -> Result<Vec<Tenant>> {
    let names = args
        .get("models")
        .or_else(|| args.get("model"))
        .unwrap_or("bert-large");
    names
        .split(',')
        .map(|n| {
            zoo::by_name(n.trim())
                .map(|m| Tenant::new(m, 1.0))
                .ok_or_else(|| Error::config(format!("unknown model {n}")))
        })
        .collect()
}

/// Run the serve subcommand.
pub fn serve_cmd(args: &Args, opts: &ExpOptions) -> Result<()> {
    let array = parse_array(args.get_or("array", "32x32"))?;
    let pods: usize = args.get_parse("pods").unwrap_or(256);
    let cfg = ArchConfig::with_array(array, pods);
    cfg.validate()?;

    let tenants = tenants_from(args)?;
    let qps: f64 = args.get_parse("qps").unwrap_or(1000.0);
    let seed: u64 = args.get_parse("seed").unwrap_or(42);
    let duration_s: f64 = args.get_parse("duration").unwrap_or(1.0);
    let partitioned = args.flag("partitioned");

    let mut ecfg = EngineConfig {
        policy: BatchPolicy {
            max_batch: args.get_parse("max-batch").unwrap_or(8),
            max_wait_s: args.get_parse::<f64>("max-wait-ms").unwrap_or(2.0) * 1e-3,
        },
        ..Default::default()
    };
    if let Some(cap) = args.get_parse::<usize>("max-queue") {
        ecfg.admission = Admission::MaxQueue(cap);
    }
    if let Some(k) = args.get_parse::<usize>("coschedule") {
        ecfg.coschedule = k;
    }
    if args.flag("per-layer") {
        // Per-layer tiling-strategy selection at batch-compile time
        // (never worse than the global r×r default; see crate::compile).
        ecfg.sim.spec = TilingSpec::auto();
    }

    // Deadline: explicit, or 5× the mix's batched per-request service
    // time — deterministic, so seeded runs stay byte-identical.
    let capacity = capacity_qps(&cfg, &tenants, &ecfg);
    let deadline_s = match args.get_parse::<f64>("deadline-ms") {
        Some(ms) => ms * 1e-3,
        None => default_deadline(ecfg.policy.max_batch, capacity),
    };

    let mode = if partitioned { "partitioned" } else { "shared" };
    println!(
        "serving {} on {} pods of {} ({mode}), seed {seed}",
        tenants.iter().map(|t| t.name.as_str()).collect::<Vec<_>>().join("+"),
        cfg.num_pods,
        cfg.array
    );
    println!(
        "policy   : max_batch {}, max_wait {:.3} ms, est. capacity {:.1} req/s",
        ecfg.policy.max_batch,
        ecfg.policy.max_wait_s * 1e3,
        capacity
    );

    if args.flag("sweep") {
        // Probe around the estimated capacity to expose the knee.
        let ladder: Vec<f64> = SWEEP_LADDER
            .iter()
            .map(|&x| x * if qps > 0.0 && args.get("qps").is_some() { qps } else { capacity })
            .collect();
        let sweep = SweepOptions {
            qps: ladder,
            duration_s,
            deadline_s,
            seed,
            partitioned,
            threads: args.get_parse::<usize>("threads"),
        };
        let points = load_sweep(&cfg, &tenants, &ecfg, &sweep)?;
        println!("{}", sweep_table(&points).render());
        match max_sustainable_qps(&points, deadline_s) {
            Some(q) => println!(
                "max sustainable load: {q:.1} req/s at p99 <= {:.3} ms",
                deadline_s * 1e3
            ),
            None => println!(
                "no probed rate sustained p99 <= {:.3} ms without shedding",
                deadline_s * 1e3
            ),
        }
        write_sweep_csv(format!("{}/serve_sweep.csv", opts.out_dir), &points)?;
        return Ok(());
    }

    let spec = TrafficSpec::poisson(qps, duration_s, seed);
    let arrivals = generate(&spec, &tenants);
    println!(
        "traffic  : Poisson {qps:.1} req/s for {duration_s:.2} s → {} arrivals",
        arrivals.len()
    );
    let rep = if partitioned {
        // `--threads N` pins the partition fan-out too (not just sweeps).
        serve_partitioned_threads(
            &cfg,
            &tenants,
            &arrivals,
            &ecfg,
            args.get_parse::<usize>("threads"),
        )?
    } else {
        serve_shared(&cfg, &tenants, &arrivals, &ecfg)
    };
    let slo = analyze(&rep, duration_s, deadline_s);
    println!("{slo}");
    println!(
        "engine   : {} batches, {} simulator calls (memoized)",
        rep.batches, rep.sim_calls
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn serve_cmd_runs_on_a_small_config() {
        let dir = std::env::temp_dir().join("sosa_serve_cmd");
        let opts = ExpOptions { out_dir: dir.to_str().unwrap().into(), quick: true };
        let a = args(
            "serve --model bert-medium --pods 16 --qps 50 --duration 0.05 \
             --seed 7 --max-batch 4",
        );
        serve_cmd(&a, &opts).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_cmd_accepts_per_layer_and_extended_models() {
        let dir = std::env::temp_dir().join("sosa_serve_cmd_pl");
        let opts = ExpOptions { out_dir: dir.to_str().unwrap().into(), quick: true };
        let a = args(
            "serve --model vit-base --pods 16 --qps 20 --duration 0.02 \
             --seed 7 --max-batch 2 --per-layer",
        );
        serve_cmd(&a, &opts).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_unknown_model_and_bad_array() {
        let opts = ExpOptions::default();
        assert!(serve_cmd(&args("serve --model vgg19 --pods 16"), &opts).is_err());
        assert!(serve_cmd(&args("serve --array 32 --pods 16"), &opts).is_err());
    }
}
