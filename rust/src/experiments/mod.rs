//! Regeneration of every table and figure in the paper's evaluation
//! (§6).  Each experiment prints the same rows/series the paper reports
//! and writes a CSV under the output directory; EXPERIMENTS.md records
//! paper-vs-measured for each.
//!
//! | id     | paper content                              |
//! |--------|--------------------------------------------|
//! | fig4   | workload dimension distributions           |
//! | fig5   | iso-power DSE heatmaps (CNN/BERT/mixed)    |
//! | table1 | interconnect metrics (busy %, cyc/op, mW/B)|
//! | table2 | array granularity @400 W                   |
//! | fig9   | per-benchmark effective throughput         |
//! | fig10  | effective throughput vs TDP                |
//! | fig11  | batch size & multi-tenancy                 |
//! | fig12a | interconnect type vs TDP                   |
//! | fig12b | activation partition size sweep            |
//! | fig13  | SRAM bank size sweep                       |
//! | table3 | power & area breakdown                     |
//!
//! Beyond the paper: `perlayer` — per-layer tiling-strategy selection
//! (analytic + exhaustive, via the compile pipeline) vs the best
//! global strategy, `ablation` — scheduler design ablations, `fleet`
//! — goodput-vs-node-count scaling of a multi-accelerator cluster
//! under round-robin vs join-shortest-queue dispatch
//! ([`crate::cluster`]), and `chaos` — goodput retained under one
//! node loss at peak load ([`crate::cluster::chaos`]).
//!
//! The sweep-shaped experiments (table1/table2/fig9/fig10/fig12a/
//! fig12b) are *declarative*: each builds a
//! [`crate::explore::DesignSpace`] over the relevant axes and formats
//! the evaluated records, instead of hand-rolling config mutations and
//! simulation loops.  Their CSV outputs are byte-identical to the
//! pre-`explore` implementations (pinned by `tests/golden.rs`);
//! shared starting points come from the [`crate::arch::presets`]
//! registry.

pub mod ablation;
pub mod chaos_exp;
pub mod fleet_exp;
pub mod granularity;
pub mod interconnect_exp;
pub mod memory_exp;
pub mod scaling;
pub mod serving_exp;
pub mod tiling_exp;
pub mod workload_stats;

use crate::Result;

/// Common experiment options.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    /// Output directory for CSVs.
    pub out_dir: String,
    /// Reduced sweep sizes for fast runs.
    pub quick: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions { out_dir: "results".into(), quick: false }
    }
}

/// Run one experiment by id.
pub fn run(id: &str, opts: &ExpOptions) -> Result<()> {
    match id {
        "fig4" => workload_stats::fig4(opts),
        "fig5" => workload_stats::fig5(opts),
        "table1" => interconnect_exp::table1(opts),
        "table2" => granularity::table2(opts),
        "fig9" => granularity::fig9(opts),
        "fig10" => scaling::fig10(opts),
        "fig11" => scaling::fig11(opts),
        "fig12a" => interconnect_exp::fig12a(opts),
        "fig12b" => tiling_exp::fig12b(opts),
        "fig13" => memory_exp::fig13(opts),
        "table3" => memory_exp::table3(opts),
        "ablation" => ablation::ablation(opts),
        "perlayer" => tiling_exp::perlayer(opts),
        "fleet" => fleet_exp::fleet(opts),
        "chaos" => chaos_exp::chaos(opts),
        other => Err(crate::Error::config(format!("unknown experiment {other}"))),
    }
}

/// All experiment ids, in paper order (paper-beyond experiments last).
pub const ALL: &[&str] = &[
    "fig4", "fig5", "table1", "table2", "fig9", "fig10", "fig11", "fig12a",
    "fig12b", "fig13", "table3", "ablation", "perlayer", "fleet", "chaos",
];

/// Run the full suite.
pub fn run_all(opts: &ExpOptions) -> Result<()> {
    for id in ALL {
        println!("\n################ {id} ################");
        run(id, opts)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_errors() {
        assert!(run("fig99", &ExpOptions::default()).is_err());
    }
}
