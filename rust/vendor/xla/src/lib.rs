//! API stub for the `xla` PJRT binding.
//!
//! Mirrors the subset of xla-rs 0.1.6 that `sosa::runtime` consumes:
//! client/executable construction, HLO-text loading and literal
//! conversion.  Every entry point type-checks like the real binding but
//! [`PjRtClient::cpu`] returns an error, so code paths gated on artifact
//! availability (all `sosa` runtime tests) skip cleanly instead of
//! linking against a native library the build environment lacks.

use std::borrow::Borrow;
use std::fmt;

/// Error type matching the real binding's shape (message-carrying).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any message.
    pub fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Result alias used by all stub entry points.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error::new(
        "xla stub: PJRT is unavailable in this build (the vendored \
         `xla` crate is an API stub; link the real xla_extension \
         binding to execute artifacts)",
    ))
}

/// Element types a [`Literal`] can be built from.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side tensor value.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO **text** file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// A computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap an HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Synchronous copy back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute on literal arguments; `[replica][output]` buffers.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// CPU client — always fails in the stub (no native PJRT linked).
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    /// Platform name (unreachable without a client, kept for API parity).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation (unreachable without a client).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not link PJRT");
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_construction_is_cheap() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
